//! Temporal/channel output reordering (Fig 13).
//!
//! The KTBC loop finishes the input-channel dimension `C` before the time
//! dimension `T`, but finishes the output-channel dimension `K` *after*
//! `T`. Written naively, layer *n*'s output lands in `(k, t)` order while
//! layer *n+1* wants to stream `(t, c)`-major input sequentially. The
//! hardware therefore computes a strided write address so the Output SRAM
//! (and DRAM) hold data in the next layer's natural read order:
//!
//! - other layers: produced `(k, t)` → stored `(t, k)`;
//! - encoding layer: produced `(k, b, t)` (bit planes) → stored `(t, k)`
//!   with the bit planes split and serialized first (Fig 13a).
//!
//! The payload is representation-agnostic: with the compressed activation
//! data path the reordered elements are word-packed
//! [`crate::sparse::SpikePlane`] tiles (1 bit/neuron), so the reorder
//! buffers shrink 8× relative to byte-per-spike storage — same addresses,
//! smaller words.

/// Write address (in elements) for the output produced at output channel
/// `k` of `num_k`, time step `t` of `num_t`, so that storage is
/// `(t, k)`-major — the next layer's sequential read order.
pub fn write_address(k: usize, t: usize, num_k: usize, num_t: usize) -> usize {
    debug_assert!(k < num_k && t < num_t);
    t * num_k + k
}

/// Read address for the *producing* order — `(k, t)`-major — used to
/// verify that reorder-on-write equals store-then-permute.
pub fn produce_order_index(k: usize, t: usize, num_t: usize) -> usize {
    k * num_t + t
}

/// Apply the reorder to a buffer laid out `(k, t)`-major, returning the
/// `(t, k)`-major buffer the hardware would have produced with strided
/// writes. `elem` values are whole tiles in the real datapath; any `Clone`
/// payload works here.
pub fn reorder_kt_to_tk<T: Clone>(data: &[T], num_k: usize, num_t: usize) -> Vec<T> {
    assert_eq!(data.len(), num_k * num_t);
    let mut out: Vec<T> = Vec::with_capacity(data.len());
    for t in 0..num_t {
        for k in 0..num_k {
            out.push(data[produce_order_index(k, t, num_t)].clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::run_prop;

    #[test]
    fn fig13_example() {
        // 3 output channels × 2 time steps produced (k,t)-major:
        // [k0t0, k0t1, k1t0, k1t1, k2t0, k2t1]
        let produced = vec!["k0t0", "k0t1", "k1t0", "k1t1", "k2t0", "k2t1"];
        let stored = reorder_kt_to_tk(&produced, 3, 2);
        assert_eq!(stored, vec!["k0t0", "k1t0", "k2t0", "k0t1", "k1t1", "k2t1"]);
    }

    #[test]
    fn write_address_is_inverse_of_produce_order() {
        run_prop("reorder/write-addr-inverse", |g| {
            let num_k = g.usize(1, 16);
            let num_t = g.usize(1, 4);
            let mut hit = vec![false; num_k * num_t];
            for k in 0..num_k {
                for t in 0..num_t {
                    let a = write_address(k, t, num_k, num_t);
                    assert!(!hit[a], "address collision");
                    hit[a] = true;
                }
            }
            assert!(hit.iter().all(|&h| h), "addresses cover the buffer");
        });
    }

    #[test]
    fn strided_write_equals_permute() {
        run_prop("reorder/strided-equals-permute", |g| {
            let num_k = g.usize(1, 8);
            let num_t = g.usize(1, 4);
            let data: Vec<u32> = g.vec(num_k * num_t, |g| g.rng().next_u32());
            // Simulate strided writes.
            let mut strided = vec![0u32; data.len()];
            for k in 0..num_k {
                for t in 0..num_t {
                    strided[write_address(k, t, num_k, num_t)] =
                        data[produce_order_index(k, t, num_t)];
                }
            }
            assert_eq!(strided, reorder_kt_to_tk(&data, num_k, num_t));
        });
    }

    #[test]
    fn single_time_step_is_identity() {
        let data = vec![10, 20, 30];
        assert_eq!(reorder_kt_to_tk(&data, 3, 1), data);
    }

    #[test]
    fn reorders_compressed_spike_tiles() {
        // The real datapath payload: compressed spike tiles ride through
        // the same strided-write addressing untouched.
        use crate::sparse::SpikePlane;
        let tiles: Vec<SpikePlane> = (0..4)
            .map(|i| {
                let mut p = SpikePlane::zeros(2, 2);
                p.set(i / 2, i % 2);
                p
            })
            .collect();
        let stored = reorder_kt_to_tk(&tiles, 2, 2);
        // (k,t)-major [k0t0, k0t1, k1t0, k1t1] → (t,k) [k0t0, k1t0, k0t1, k1t1]
        assert_eq!(stored[0], tiles[0]);
        assert_eq!(stored[1], tiles[2]);
        assert_eq!(stored[2], tiles[1]);
        assert_eq!(stored[3], tiles[3]);
    }
}
