//! External DRAM traffic + energy model (§IV-D), and the inter-chip
//! interconnect model of the multi-chip cluster subsystem.
//!
//! The paper assumes DDR3 at 70 pJ/bit [35] and reports, for one
//! 1024×576 frame: 188.928 MB of input traffic (the last layers refetch
//! inputs from DRAM for every output channel because the 36 KB input SRAM
//! holds only one time step), 3.327 MB of output traffic, and 1.292 MB of
//! parameter traffic; growing the input SRAM to 81 KB cuts input traffic
//! to 5.456 MB. This module computes those numbers from the network
//! geometry, the SRAM capacities, and the weight compression format.
//!
//! **Inter-chip interconnect** ([`LinkSpec`] / [`Interconnect`]): when a
//! frame is sharded across chips (`crate::cluster`), spike planes ship
//! between chips over a DRAM-class link — per-transfer latency plus a
//! bandwidth term, energy per bit, and per-chip traffic counters. Spike
//! payloads are priced from popcounts ([`spike_map_transfer_bits`]):
//! activations are binary events, so the sender streams cell-indexed
//! event addresses ([`event_addr_bits`], ≥16 bits, 20 at the paper's
//! 1024×576) and falls back to the raw bitmap when the plane is dense —
//! the same compression argument the paper makes for weights (Fig 17),
//! applied to the traffic that memory-dominated SNN accelerators actually
//! move (Sommer et al., arXiv 2203.12437).

use crate::config::{AccelConfig, ClusterConfig};
use crate::sparse::SpikeMap;
use crate::model::topology::{ConvKind, NetworkSpec};
use crate::model::weights::ModelWeights;
use crate::sparse::stats::{format_bits, Format};

/// Traffic breakdown for one frame, in bits.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DramTraffic {
    /// Input activation bits fetched.
    pub input_bits: u64,
    /// Output activation bits written.
    pub output_bits: u64,
    /// Parameter bits fetched.
    pub param_bits: u64,
}

impl DramTraffic {
    /// Total bits moved.
    pub fn total_bits(&self) -> u64 {
        self.input_bits + self.output_bits + self.param_bits
    }

    /// Energy at `pj_per_bit`, in millijoules.
    pub fn energy_mj(&self, pj_per_bit: f64) -> f64 {
        self.total_bits() as f64 * pj_per_bit * 1e-12 * 1e3
    }

    /// Megabytes of a bit count (the paper's unit).
    pub fn mb(bits: u64) -> f64 {
        bits as f64 / 8.0 / 1e6
    }
}

/// DRAM model bound to an accelerator configuration.
#[derive(Clone, Debug)]
pub struct DramModel {
    cfg: AccelConfig,
}

impl DramModel {
    /// New model.
    pub fn new(cfg: AccelConfig) -> Self {
        DramModel { cfg }
    }

    /// Compute one frame's traffic for `net` with `weights` compressed as
    /// `fmt`.
    ///
    /// Input policy (matches §IV-D's description): a layer's input working
    /// set is `c_in × in_t × tile` spike bits (×8 bit planes for the
    /// encoding layer). If the full set fits the Input SRAM, each input is
    /// fetched exactly once. Otherwise the SRAM pins the first time step
    /// and the remaining `in_t − 1` steps are re-streamed from DRAM for
    /// **every output channel** (the KTBC loop has K outermost).
    pub fn frame_traffic(
        &self,
        net: &NetworkSpec,
        weights: &ModelWeights,
        fmt: Format,
    ) -> DramTraffic {
        let mut t = DramTraffic::default();
        let tile_bits = (self.cfg.tile_h * self.cfg.tile_w) as u64; // 1 bit/spike
        for l in &net.layers {
            let tiles_x = l.in_w.div_ceil(self.cfg.tile_w) as u64;
            let tiles_y = l.in_h.div_ceil(self.cfg.tile_h) as u64;
            let n_tiles = tiles_x * tiles_y;
            let planes = if l.kind == ConvKind::Encoding { 8 } else { 1 } as u64;
            let step_bits_per_tile = l.c_in as u64 * tile_bits * planes;
            let working_set_bits = step_bits_per_tile * l.in_t as u64;
            let fits = (working_set_bits / 8) as usize <= self.cfg.input_sram_bytes;
            let per_tile_input = if fits || l.in_t == 1 {
                working_set_bits
            } else {
                // First step resident; later steps re-fetched per output
                // channel (§IV-D).
                step_bits_per_tile
                    + step_bits_per_tile * (l.in_t as u64 - 1) * l.c_out as u64
            };
            t.input_bits += per_tile_input * n_tiles;

            // Output writes: spikes for hidden layers (after any pooling),
            // 16-bit accumulators for the head.
            let (ow, oh) = (l.out_w() as u64, l.out_h() as u64);
            let out_bits_per_elem = if l.kind == ConvKind::Output { 16 } else { 1 } as u64;
            t.output_bits += l.c_out as u64 * ow * oh * l.out_t as u64 * out_bits_per_elem;

            // Parameters: streamed once per frame per layer in `fmt`.
            if let Some(lw) = weights.get(&l.name) {
                t.param_bits += format_bits(&lw.w, fmt, self.cfg.weight_bits).bits as u64;
            }
        }
        t
    }

    /// Energy for one frame's traffic in mJ.
    pub fn frame_energy_mj(&self, traffic: &DramTraffic) -> f64 {
        traffic.energy_mj(self.cfg.dram_pj_per_bit)
    }

    /// Sustained bandwidth requirement in GB/s at a target fps.
    pub fn bandwidth_gbs(&self, traffic: &DramTraffic, fps: f64) -> f64 {
        traffic.total_bits() as f64 / 8.0 * fps / 1e9
    }
}

/// One inter-chip link: bandwidth, fixed latency, energy per bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSpec {
    /// Bits moved per core-clock cycle.
    pub bits_per_cycle: u64,
    /// Fixed per-transfer latency in core-clock cycles.
    pub latency_cycles: u64,
    /// Energy per bit in picojoules.
    pub pj_per_bit: f64,
}

impl Default for LinkSpec {
    fn default() -> Self {
        LinkSpec { bits_per_cycle: 128, latency_cycles: 200, pj_per_bit: 10.0 }
    }
}

impl LinkSpec {
    /// The link a [`ClusterConfig`] describes.
    pub fn from_cluster(cc: &ClusterConfig) -> LinkSpec {
        LinkSpec {
            bits_per_cycle: cc.link_bits_per_cycle.max(1),
            latency_cycles: cc.link_latency_cycles,
            pj_per_bit: cc.link_pj_per_bit,
        }
    }

    /// Cycles one transfer of `bits` occupies the link (0 bits = no
    /// transfer at all, not even the latency).
    pub fn transfer_cycles(&self, bits: u64) -> u64 {
        if bits == 0 {
            0
        } else {
            self.latency_cycles + bits.div_ceil(self.bits_per_cycle.max(1))
        }
    }

    /// Energy of moving `bits` over the link, in millijoules.
    pub fn energy_mj(&self, bits: u64) -> f64 {
        bits as f64 * self.pj_per_bit * 1e-9
    }
}

/// Per-chip interconnect counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChipTraffic {
    /// Bits received (from the host or another chip).
    pub bits_in: u64,
    /// Bits sent.
    pub bits_out: u64,
    /// Transfers received.
    pub transfers_in: u64,
    /// Transfers sent.
    pub transfers_out: u64,
}

/// One recorded transfer. `None` endpoints are the host (frame upload /
/// result download).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransferRecord {
    /// Sending chip (`None` = host).
    pub src: Option<usize>,
    /// Receiving chip (`None` = host).
    pub dst: Option<usize>,
    /// Payload bits.
    pub bits: u64,
    /// Link occupancy charged ([`LinkSpec::transfer_cycles`]).
    pub cycles: u64,
}

/// The cluster interconnect: one shared [`LinkSpec`] plus per-chip
/// traffic counters and the full transfer log. The executing cluster
/// records every transfer here; the analytic model re-prices the same log
/// with the same [`LinkSpec`] constants, so the two stay in lock-step by
/// construction (asserted in `tests/cluster_equivalence.rs`).
#[derive(Clone, Debug)]
pub struct Interconnect {
    link: LinkSpec,
    per_chip: Vec<ChipTraffic>,
    transfers: Vec<TransferRecord>,
}

impl Interconnect {
    /// New interconnect joining `chips` chips.
    pub fn new(link: LinkSpec, chips: usize) -> Interconnect {
        Interconnect {
            link,
            per_chip: vec![ChipTraffic::default(); chips.max(1)],
            transfers: Vec::new(),
        }
    }

    /// The link model.
    pub fn link(&self) -> &LinkSpec {
        &self.link
    }

    /// Record one transfer and return the cycles it occupies the link.
    /// Zero-bit sends are dropped (event-driven: nothing to move).
    pub fn send(&mut self, src: Option<usize>, dst: Option<usize>, bits: u64) -> u64 {
        if bits == 0 {
            return 0;
        }
        let cycles = self.link.transfer_cycles(bits);
        if let Some(s) = src {
            self.per_chip[s].bits_out += bits;
            self.per_chip[s].transfers_out += 1;
        }
        if let Some(d) = dst {
            self.per_chip[d].bits_in += bits;
            self.per_chip[d].transfers_in += 1;
        }
        self.transfers.push(TransferRecord { src, dst, bits, cycles });
        cycles
    }

    /// Total bits moved.
    pub fn total_bits(&self) -> u64 {
        self.transfers.iter().map(|t| t.bits).sum()
    }

    /// Total link occupancy in cycles (transfers serialized).
    pub fn total_cycles(&self) -> u64 {
        self.transfers.iter().map(|t| t.cycles).sum()
    }

    /// Total link energy in millijoules.
    pub fn energy_mj(&self) -> f64 {
        self.link.energy_mj(self.total_bits())
    }

    /// Per-chip counters.
    pub fn per_chip(&self) -> &[ChipTraffic] {
        &self.per_chip
    }

    /// The transfer log.
    pub fn transfers(&self) -> &[TransferRecord] {
        &self.transfers
    }
}

/// Bits needed to address one of `cells` positions, halfword-aligned:
/// `max(16, ceil(log2(cells)))` — a full-scale 1024×576 plane needs
/// 20-bit addresses, a tile-sized strip still ships 16-bit ones.
pub fn event_addr_bits(cells: u64) -> u64 {
    (64 - cells.saturating_sub(1).leading_zeros() as u64).max(16)
}

/// Compressed transfer cost of `nnz` spike events in a plane of `cells`
/// positions: a 32-bit count header plus one cell-indexed address per
/// event ([`event_addr_bits`]), capped by the raw bitmap (the sender
/// switches representation when events are denser than 1/addr_bits).
pub fn spike_plane_transfer_bits(cells: u64, nnz: u64) -> u64 {
    32 + (nnz * event_addr_bits(cells)).min(cells)
}

/// Compressed transfer cost of one spike map (all planes).
pub fn spike_map_transfer_bits(map: &SpikeMap) -> u64 {
    let cells = (map.h * map.w) as u64;
    (0..map.c)
        .map(|c| spike_plane_transfer_bits(cells, map.plane(c).count_set() as u64))
        .sum()
}

/// Transfer cost of one multibit pixel frame (8 bits per value — not
/// compressible the way binary spikes are).
pub fn pixel_frame_bits(c: usize, h: usize, w: usize) -> u64 {
    (c * h * w) as u64 * 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::topology::{Scale, TimeStepConfig};

    fn full_net() -> (NetworkSpec, ModelWeights) {
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut w = ModelWeights::random(&net, 1.0, 42);
        w.prune_fine_grained(0.8);
        (net, w)
    }

    #[test]
    fn small_sram_forces_refetch() {
        let (net, w) = full_net();
        let small = DramModel::new(AccelConfig::paper());
        let large = DramModel::new(AccelConfig::paper_large_input_sram());
        let ts = small.frame_traffic(&net, &w, Format::BitMask);
        let tl = large.frame_traffic(&net, &w, Format::BitMask);
        // §IV-D: enlarging input SRAM slashes input traffic by >10×.
        assert!(
            ts.input_bits > 10 * tl.input_bits,
            "small={} large={}",
            DramTraffic::mb(ts.input_bits),
            DramTraffic::mb(tl.input_bits)
        );
        // Output/param traffic unaffected.
        assert_eq!(ts.output_bits, tl.output_bits);
        assert_eq!(ts.param_bits, tl.param_bits);
    }

    #[test]
    fn traffic_magnitudes_match_paper_shape() {
        // Paper: input 188.9 MB, output 3.3 MB, params 1.3 MB per frame.
        // Our geometry differs in detail; check orders of magnitude.
        let (net, w) = full_net();
        let m = DramModel::new(AccelConfig::paper());
        let t = m.frame_traffic(&net, &w, Format::BitMask);
        let input_mb = DramTraffic::mb(t.input_bits);
        let output_mb = DramTraffic::mb(t.output_bits);
        let param_mb = DramTraffic::mb(t.param_bits);
        assert!((50.0..400.0).contains(&input_mb), "input={input_mb}");
        assert!((0.5..10.0).contains(&output_mb), "output={output_mb}");
        assert!((0.2..4.0).contains(&param_mb), "params={param_mb}");
        // Input dominates by ~2 orders of magnitude, as in the paper.
        assert!(input_mb > 20.0 * output_mb);
    }

    #[test]
    fn format_ordering_dense_csr_bitmask() {
        // Fig 17: dense > CSR > bit-mask for parameter traffic.
        let (net, w) = full_net();
        let m = DramModel::new(AccelConfig::paper());
        let dense = m.frame_traffic(&net, &w, Format::Dense).param_bits;
        let csr = m.frame_traffic(&net, &w, Format::Csr).param_bits;
        let bm = m.frame_traffic(&net, &w, Format::BitMask).param_bits;
        assert!(dense > csr && csr > bm, "{dense} {csr} {bm}");
        // Paper: bit-mask saves 59.1% vs dense and 16.4% vs CSR.
        let vs_dense = 1.0 - bm as f64 / dense as f64;
        let vs_csr = 1.0 - bm as f64 / csr as f64;
        assert!((0.35..0.75).contains(&vs_dense), "vs_dense={vs_dense}");
        assert!((0.05..0.35).contains(&vs_csr), "vs_csr={vs_csr}");
    }

    #[test]
    fn energy_arithmetic() {
        let t = DramTraffic { input_bits: 1_000_000, output_bits: 0, param_bits: 0 };
        // 1e6 bits × 70 pJ = 70 µJ = 0.07 mJ.
        assert!((t.energy_mj(70.0) - 0.07).abs() < 1e-9);
    }

    #[test]
    fn link_transfer_cost_model() {
        let l = LinkSpec { bits_per_cycle: 100, latency_cycles: 10, pj_per_bit: 2.0 };
        assert_eq!(l.transfer_cycles(0), 0);
        assert_eq!(l.transfer_cycles(1), 11);
        assert_eq!(l.transfer_cycles(100), 11);
        assert_eq!(l.transfer_cycles(101), 12);
        // 1000 bits × 2 pJ = 2 nJ = 2e-6 mJ.
        assert!((l.energy_mj(1000) - 2e-6).abs() < 1e-15);
    }

    #[test]
    fn interconnect_counts_per_chip() {
        let mut ic = Interconnect::new(LinkSpec::default(), 3);
        let c0 = ic.send(None, Some(0), 1024); // host upload
        let c1 = ic.send(Some(0), Some(2), 512);
        assert_eq!(ic.send(Some(0), Some(1), 0), 0, "zero-bit sends are dropped");
        assert_eq!(ic.transfers().len(), 2);
        assert_eq!(ic.total_bits(), 1536);
        assert_eq!(ic.total_cycles(), c0 + c1);
        assert_eq!(ic.per_chip()[0].bits_in, 1024);
        assert_eq!(ic.per_chip()[0].bits_out, 512);
        assert_eq!(ic.per_chip()[2].bits_in, 512);
        assert_eq!(ic.per_chip()[1], ChipTraffic::default());
        assert!((ic.energy_mj() - LinkSpec::default().energy_mj(1536)).abs() < 1e-15);
    }

    #[test]
    fn spike_transfer_priced_from_popcounts() {
        use crate::tensor::Tensor;
        // Addresses widen with the plane: 16 bits up to 2^16 cells,
        // 20 bits for the paper's full-scale 1024×576 plane.
        assert_eq!(event_addr_bits(1000), 16);
        assert_eq!(event_addr_bits(1 << 16), 16);
        assert_eq!(event_addr_bits((1 << 16) + 1), 17);
        assert_eq!(event_addr_bits(1024 * 576), 20);
        // Sparse plane: events win. Dense plane: bitmap cap kicks in.
        assert_eq!(spike_plane_transfer_bits(1000, 3), 32 + 48);
        assert_eq!(spike_plane_transfer_bits(1000, 900), 32 + 1000);
        assert_eq!(spike_plane_transfer_bits(1024 * 576, 10), 32 + 200);
        let mut dense = Tensor::zeros(2, 4, 8);
        for v in dense.data.iter_mut() {
            *v = 1;
        }
        let full = SpikeMap::from_dense(&dense);
        let empty = SpikeMap::zeros(2, 4, 8);
        assert_eq!(spike_map_transfer_bits(&full), 2 * (32 + 32));
        assert_eq!(spike_map_transfer_bits(&empty), 2 * 32);
        assert!(spike_map_transfer_bits(&empty) < spike_map_transfer_bits(&full));
        assert_eq!(pixel_frame_bits(3, 4, 8), 3 * 4 * 8 * 8);
    }
}
