//! External DRAM traffic + energy model (§IV-D).
//!
//! The paper assumes DDR3 at 70 pJ/bit [35] and reports, for one
//! 1024×576 frame: 188.928 MB of input traffic (the last layers refetch
//! inputs from DRAM for every output channel because the 36 KB input SRAM
//! holds only one time step), 3.327 MB of output traffic, and 1.292 MB of
//! parameter traffic; growing the input SRAM to 81 KB cuts input traffic
//! to 5.456 MB. This module computes those numbers from the network
//! geometry, the SRAM capacities, and the weight compression format.

use crate::config::AccelConfig;
use crate::model::topology::{ConvKind, NetworkSpec};
use crate::model::weights::ModelWeights;
use crate::sparse::stats::{format_bits, Format};

/// Traffic breakdown for one frame, in bits.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DramTraffic {
    /// Input activation bits fetched.
    pub input_bits: u64,
    /// Output activation bits written.
    pub output_bits: u64,
    /// Parameter bits fetched.
    pub param_bits: u64,
}

impl DramTraffic {
    /// Total bits moved.
    pub fn total_bits(&self) -> u64 {
        self.input_bits + self.output_bits + self.param_bits
    }

    /// Energy at `pj_per_bit`, in millijoules.
    pub fn energy_mj(&self, pj_per_bit: f64) -> f64 {
        self.total_bits() as f64 * pj_per_bit * 1e-12 * 1e3
    }

    /// Megabytes of a bit count (the paper's unit).
    pub fn mb(bits: u64) -> f64 {
        bits as f64 / 8.0 / 1e6
    }
}

/// DRAM model bound to an accelerator configuration.
#[derive(Clone, Debug)]
pub struct DramModel {
    cfg: AccelConfig,
}

impl DramModel {
    /// New model.
    pub fn new(cfg: AccelConfig) -> Self {
        DramModel { cfg }
    }

    /// Compute one frame's traffic for `net` with `weights` compressed as
    /// `fmt`.
    ///
    /// Input policy (matches §IV-D's description): a layer's input working
    /// set is `c_in × in_t × tile` spike bits (×8 bit planes for the
    /// encoding layer). If the full set fits the Input SRAM, each input is
    /// fetched exactly once. Otherwise the SRAM pins the first time step
    /// and the remaining `in_t − 1` steps are re-streamed from DRAM for
    /// **every output channel** (the KTBC loop has K outermost).
    pub fn frame_traffic(
        &self,
        net: &NetworkSpec,
        weights: &ModelWeights,
        fmt: Format,
    ) -> DramTraffic {
        let mut t = DramTraffic::default();
        let tile_bits = (self.cfg.tile_h * self.cfg.tile_w) as u64; // 1 bit/spike
        for l in &net.layers {
            let tiles_x = l.in_w.div_ceil(self.cfg.tile_w) as u64;
            let tiles_y = l.in_h.div_ceil(self.cfg.tile_h) as u64;
            let n_tiles = tiles_x * tiles_y;
            let planes = if l.kind == ConvKind::Encoding { 8 } else { 1 } as u64;
            let step_bits_per_tile = l.c_in as u64 * tile_bits * planes;
            let working_set_bits = step_bits_per_tile * l.in_t as u64;
            let fits = (working_set_bits / 8) as usize <= self.cfg.input_sram_bytes;
            let per_tile_input = if fits || l.in_t == 1 {
                working_set_bits
            } else {
                // First step resident; later steps re-fetched per output
                // channel (§IV-D).
                step_bits_per_tile
                    + step_bits_per_tile * (l.in_t as u64 - 1) * l.c_out as u64
            };
            t.input_bits += per_tile_input * n_tiles;

            // Output writes: spikes for hidden layers (after any pooling),
            // 16-bit accumulators for the head.
            let (ow, oh) = (l.out_w() as u64, l.out_h() as u64);
            let out_bits_per_elem = if l.kind == ConvKind::Output { 16 } else { 1 } as u64;
            t.output_bits += l.c_out as u64 * ow * oh * l.out_t as u64 * out_bits_per_elem;

            // Parameters: streamed once per frame per layer in `fmt`.
            if let Some(lw) = weights.get(&l.name) {
                t.param_bits += format_bits(&lw.w, fmt, self.cfg.weight_bits).bits as u64;
            }
        }
        t
    }

    /// Energy for one frame's traffic in mJ.
    pub fn frame_energy_mj(&self, traffic: &DramTraffic) -> f64 {
        traffic.energy_mj(self.cfg.dram_pj_per_bit)
    }

    /// Sustained bandwidth requirement in GB/s at a target fps.
    pub fn bandwidth_gbs(&self, traffic: &DramTraffic, fps: f64) -> f64 {
        traffic.total_bits() as f64 / 8.0 * fps / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::topology::{Scale, TimeStepConfig};

    fn full_net() -> (NetworkSpec, ModelWeights) {
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut w = ModelWeights::random(&net, 1.0, 42);
        w.prune_fine_grained(0.8);
        (net, w)
    }

    #[test]
    fn small_sram_forces_refetch() {
        let (net, w) = full_net();
        let small = DramModel::new(AccelConfig::paper());
        let large = DramModel::new(AccelConfig::paper_large_input_sram());
        let ts = small.frame_traffic(&net, &w, Format::BitMask);
        let tl = large.frame_traffic(&net, &w, Format::BitMask);
        // §IV-D: enlarging input SRAM slashes input traffic by >10×.
        assert!(
            ts.input_bits > 10 * tl.input_bits,
            "small={} large={}",
            DramTraffic::mb(ts.input_bits),
            DramTraffic::mb(tl.input_bits)
        );
        // Output/param traffic unaffected.
        assert_eq!(ts.output_bits, tl.output_bits);
        assert_eq!(ts.param_bits, tl.param_bits);
    }

    #[test]
    fn traffic_magnitudes_match_paper_shape() {
        // Paper: input 188.9 MB, output 3.3 MB, params 1.3 MB per frame.
        // Our geometry differs in detail; check orders of magnitude.
        let (net, w) = full_net();
        let m = DramModel::new(AccelConfig::paper());
        let t = m.frame_traffic(&net, &w, Format::BitMask);
        let input_mb = DramTraffic::mb(t.input_bits);
        let output_mb = DramTraffic::mb(t.output_bits);
        let param_mb = DramTraffic::mb(t.param_bits);
        assert!((50.0..400.0).contains(&input_mb), "input={input_mb}");
        assert!((0.5..10.0).contains(&output_mb), "output={output_mb}");
        assert!((0.2..4.0).contains(&param_mb), "params={param_mb}");
        // Input dominates by ~2 orders of magnitude, as in the paper.
        assert!(input_mb > 20.0 * output_mb);
    }

    #[test]
    fn format_ordering_dense_csr_bitmask() {
        // Fig 17: dense > CSR > bit-mask for parameter traffic.
        let (net, w) = full_net();
        let m = DramModel::new(AccelConfig::paper());
        let dense = m.frame_traffic(&net, &w, Format::Dense).param_bits;
        let csr = m.frame_traffic(&net, &w, Format::Csr).param_bits;
        let bm = m.frame_traffic(&net, &w, Format::BitMask).param_bits;
        assert!(dense > csr && csr > bm, "{dense} {csr} {bm}");
        // Paper: bit-mask saves 59.1% vs dense and 16.4% vs CSR.
        let vs_dense = 1.0 - bm as f64 / dense as f64;
        let vs_csr = 1.0 - bm as f64 / csr as f64;
        assert!((0.35..0.75).contains(&vs_dense), "vs_dense={vs_dense}");
        assert!((0.05..0.35).contains(&vs_csr), "vs_csr={vs_csr}");
    }

    #[test]
    fn energy_arithmetic() {
        let t = DramTraffic { input_bits: 1_000_000, output_bits: 0, param_bits: 0 };
        // 1e6 bits × 70 pJ = 70 µJ = 0.07 mJ.
        assert!((t.energy_mj(70.0) - 0.07).abs() < 1e-9);
    }
}
