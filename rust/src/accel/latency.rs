//! Analytic whole-network cycle model (§IV-C/E).
//!
//! Computes, per layer and in total, the cycles the KTBC schedule takes —
//! with zero-weight skipping (the shipped design) and for the dense
//! baseline (skipping off) — without executing any arithmetic, so the
//! full-size 1024×576 network can be analyzed instantly. The same cost
//! constants drive the cycle counters of the executing
//! [`super::controller::SystemController`]; an integration test pins the
//! two models together on a small layer.

use super::controller::CycleCosts;
use crate::config::AccelConfig;
use crate::model::topology::{ConvKind, ConvSpec, NetworkSpec};
use crate::model::weights::ModelWeights;

/// Per-layer latency result.
#[derive(Clone, Debug)]
pub struct LayerLatency {
    /// Layer name.
    pub name: String,
    /// Total work in cycles with weight skipping (summed over cores).
    pub sparse_cycles: u64,
    /// Total work without skipping.
    pub dense_cycles: u64,
    /// Layer makespan with weight skipping when the tile grid is sharded
    /// round-robin across `num_cores` cores: the busiest core carries
    /// `ceil(tiles / cores)` tiles, and every tile costs the same (cycle
    /// counts depend on weights, not activations). Equals `sparse_cycles`
    /// at `num_cores = 1`.
    pub sparse_makespan: u64,
    /// Dense-baseline makespan.
    pub dense_makespan: u64,
}

/// Whole-network latency result.
#[derive(Clone, Debug, Default)]
pub struct NetworkLatency {
    /// Per-layer records in execution order.
    pub layers: Vec<LayerLatency>,
}

impl NetworkLatency {
    /// Total work in cycles with weight skipping (summed over cores).
    pub fn sparse_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.sparse_cycles).sum()
    }

    /// Total dense-baseline cycles.
    pub fn dense_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.dense_cycles).sum()
    }

    /// Frame makespan: layers run back to back, each taking its
    /// multi-core makespan. Equals [`Self::sparse_cycles`] on one core.
    pub fn sparse_makespan(&self) -> u64 {
        self.layers.iter().map(|l| l.sparse_makespan).sum()
    }

    /// Dense-baseline frame makespan.
    pub fn dense_makespan(&self) -> u64 {
        self.layers.iter().map(|l| l.dense_makespan).sum()
    }

    /// Speedup of the configured core count over the same network's total
    /// single-core work (`1.0` at one core; ≤ `num_cores` always).
    pub fn core_speedup(&self) -> f64 {
        let m = self.sparse_makespan();
        if m == 0 {
            1.0
        } else {
            self.sparse_cycles() as f64 / m as f64
        }
    }

    /// Fraction of computing latency saved by zero-weight skipping
    /// (paper: 47.3%).
    pub fn latency_saving(&self) -> f64 {
        let d = self.dense_cycles();
        if d == 0 {
            0.0
        } else {
            1.0 - self.sparse_cycles() as f64 / d as f64
        }
    }

    /// Frames per second at `clock_hz` — per-frame latency is the
    /// multi-core makespan (identical to the total cycles on one core).
    pub fn fps(&self, clock_hz: f64) -> f64 {
        clock_hz / self.sparse_makespan() as f64
    }
}

/// The analytic model.
pub struct LatencyModel {
    cfg: AccelConfig,
    costs: CycleCosts,
}

impl LatencyModel {
    /// New model with default pipeline costs.
    pub fn new(cfg: AccelConfig) -> Self {
        LatencyModel { cfg, costs: CycleCosts::default() }
    }

    /// Cycles for one layer.
    ///
    /// Per tile, the KTBC loop costs
    /// `Σ_k [ conv_t · B · Σ_c (nnz(k,c) + input_switch) + out_t · lif_wb ]`
    /// plus the tile setup; `nnz → k²` for the dense baseline.
    pub fn layer(&self, spec: &ConvSpec, lw: &crate::model::weights::LayerWeights) -> LayerLatency {
        let tiles_x = spec.in_w.div_ceil(self.cfg.tile_w) as u64;
        let tiles_y = spec.in_h.div_ceil(self.cfg.tile_h) as u64;
        let n_tiles = tiles_x * tiles_y;
        let planes = if spec.kind == ConvKind::Encoding { 8u64 } else { 1 };
        let conv_t = spec.in_t as u64;
        let out_t = if spec.kind == ConvKind::Output { spec.in_t } else { spec.out_t } as u64;

        // Σ_c nnz(k,c) per output channel.
        let mut sparse_inner = 0u64;
        for k in 0..spec.c_out {
            for c in 0..spec.c_in {
                let plane = lw.w.plane(k, c);
                sparse_inner += plane.iter().filter(|&&w| w != 0).count() as u64;
            }
        }
        let dense_inner = (spec.c_out * spec.c_in * spec.k * spec.k) as u64;
        let switches = (spec.c_out * spec.c_in) as u64 * self.costs.input_switch;
        let lif = spec.c_out as u64 * out_t * self.costs.lif_writeback;

        let per_tile_sparse = conv_t * planes * (sparse_inner + switches) + lif;
        let per_tile_dense = conv_t * planes * (dense_inner + switches) + lif;
        // Round-robin tile sharding: the busiest of the `num_cores` cores
        // carries ceil(tiles / cores) tiles — the executing controller's
        // schedule, reproduced in closed form.
        let busiest_tiles = n_tiles.div_ceil(self.cfg.num_cores.max(1) as u64);
        LayerLatency {
            name: spec.name.clone(),
            sparse_cycles: n_tiles * (per_tile_sparse + self.costs.tile_setup),
            dense_cycles: n_tiles * (per_tile_dense + self.costs.tile_setup),
            sparse_makespan: busiest_tiles * (per_tile_sparse + self.costs.tile_setup),
            dense_makespan: busiest_tiles * (per_tile_dense + self.costs.tile_setup),
        }
    }

    /// Cycles for the whole network.
    pub fn network(&self, net: &NetworkSpec, weights: &ModelWeights) -> NetworkLatency {
        NetworkLatency {
            layers: net
                .layers
                .iter()
                .map(|l| self.layer(l, weights.get(&l.name).expect("weights cover net")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::controller::SystemController;
    use crate::model::topology::{Scale, TimeStepConfig};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn analytic_matches_executed_cycles() {
        // The executing controller and the analytic model must agree
        // exactly — they implement the same cost model.
        let spec = ConvSpec {
            name: "t".into(),
            kind: ConvKind::Spike,
            c_in: 3,
            c_out: 4,
            k: 3,
            in_t: 2,
            out_t: 2,
            maxpool_after: false,
            in_w: 16,
            in_h: 12,
            concat_with: None,
            input_from: None,
        };
        let net = NetworkSpec {
            name: "t".into(),
            input_w: 16,
            input_h: 12,
            input_c: 3,
            layers: vec![spec.clone()],
            num_anchors: 5,
            num_classes: 3,
        };
        let mut mw = ModelWeights::random(&net, 1.0, 7);
        mw.prune_fine_grained(0.7);
        let lw = mw.get("t").unwrap();

        let cfg = AccelConfig { tile_w: 8, tile_h: 6, ..AccelConfig::paper() };
        let analytic = LatencyModel::new(cfg.clone()).layer(&spec, lw);

        let mut rng = Rng::new(8);
        let inputs: Vec<crate::sparse::SpikeMap> = (0..2)
            .map(|_| {
                let n = 3 * 12 * 16;
                crate::sparse::SpikeMap::from_dense(&Tensor::from_vec(
                    3,
                    12,
                    16,
                    (0..n).map(|_| u8::from(rng.chance(0.3))).collect(),
                ))
            })
            .collect();
        let run = SystemController::new(cfg)
            .run_layer(&spec, lw, crate::accel::controller::LayerInput::Spikes(&inputs))
            .unwrap();
        assert_eq!(run.cycles, analytic.sparse_cycles);
        assert_eq!(run.dense_cycles, analytic.dense_cycles);
        assert_eq!(analytic.sparse_makespan, analytic.sparse_cycles, "one core: makespan = total");
    }

    #[test]
    fn multicore_makespan_in_lockstep_with_controller() {
        // The extended analytic model and the executing controller must
        // agree exactly on the multi-core layer makespan — including a
        // tile count (2×3 = 6 on a 16×18 map with 8×6 tiles) that does
        // not divide evenly by the core count.
        let spec = ConvSpec {
            name: "t".into(),
            kind: ConvKind::Spike,
            c_in: 3,
            c_out: 4,
            k: 3,
            in_t: 2,
            out_t: 2,
            maxpool_after: false,
            in_w: 16,
            in_h: 18,
            concat_with: None,
            input_from: None,
        };
        let net = NetworkSpec {
            name: "t".into(),
            input_w: 16,
            input_h: 18,
            input_c: 3,
            layers: vec![spec.clone()],
            num_anchors: 5,
            num_classes: 3,
        };
        let mut mw = ModelWeights::random(&net, 1.0, 12);
        mw.prune_fine_grained(0.7);
        let lw = mw.get("t").unwrap();
        let mut rng = Rng::new(13);
        let inputs: Vec<crate::sparse::SpikeMap> = (0..2)
            .map(|_| {
                let n = 3 * 18 * 16;
                crate::sparse::SpikeMap::from_dense(&Tensor::from_vec(
                    3,
                    18,
                    16,
                    (0..n).map(|_| u8::from(rng.chance(0.3))).collect(),
                ))
            })
            .collect();
        for cores in [1usize, 2, 3, 4, 6, 8] {
            let cfg =
                AccelConfig { tile_w: 8, tile_h: 6, ..AccelConfig::paper() }.with_cores(cores);
            let analytic = LatencyModel::new(cfg.clone()).layer(&spec, lw);
            let run = SystemController::new(cfg)
                .run_layer(&spec, lw, crate::accel::controller::LayerInput::Spikes(&inputs))
                .unwrap();
            assert_eq!(run.cycles, analytic.sparse_makespan, "cores={cores}");
            assert_eq!(run.dense_cycles, analytic.dense_makespan, "cores={cores}");
            assert_eq!(run.total_cycles(), analytic.sparse_cycles, "cores={cores}");
        }
    }

    #[test]
    fn core_speedup_saturates_at_tile_count() {
        // A layer with 6 tiles cannot speed up past 6×, and speedup is
        // monotone in the core count.
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 14);
        mw.prune_fine_grained(0.8);
        let mut prev = 0.0f64;
        for cores in [1usize, 2, 4, 8, 16] {
            let lat =
                LatencyModel::new(AccelConfig::paper().with_cores(cores)).network(&net, &mw);
            let s = lat.core_speedup();
            assert!(s >= prev, "cores={cores}: speedup regressed {s} < {prev}");
            assert!(s <= cores as f64 + 1e-9, "cores={cores}: superlinear {s}");
            prev = s;
        }
    }

    #[test]
    fn paper_pruning_gives_paper_scale_saving() {
        // §IV-E: zero-weight skipping saves ~47.3% of computing latency at
        // the paper's pruning rate.
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 9);
        mw.prune_fine_grained(0.8);
        let lat = LatencyModel::new(AccelConfig::paper()).network(&net, &mw);
        let saving = lat.latency_saving();
        assert!((0.30..0.70).contains(&saving), "saving={saving}");
    }

    #[test]
    fn full_network_fps_near_paper() {
        // Paper: 29 fps at 500 MHz for 1024×576. Our geometry differs in
        // detail; require the same order of magnitude.
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 10);
        mw.prune_fine_grained(0.8);
        let lat = LatencyModel::new(AccelConfig::paper()).network(&net, &mw);
        let fps = lat.fps(500e6);
        assert!((5.0..120.0).contains(&fps), "fps={fps}");
    }

    #[test]
    fn mixed_time_steps_cut_cycles() {
        let mw_of = |ts| {
            let net = NetworkSpec::paper(Scale::Full, ts);
            let mut mw = ModelWeights::random(&net, 1.0, 11);
            mw.prune_fine_grained(0.8);
            (net, mw)
        };
        let (n3, w3) = mw_of(TimeStepConfig::Uniform(3));
        let (nc2, wc2) = mw_of(TimeStepConfig::C2(3));
        let m = LatencyModel::new(AccelConfig::paper());
        assert!(m.network(&nc2, &wc2).sparse_cycles() < m.network(&n3, &w3).sparse_cycles());
    }
}
