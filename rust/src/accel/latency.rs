//! Analytic whole-network cycle model (§IV-C/E).
//!
//! Computes, per layer and in total, the cycles the KTBC schedule takes —
//! with zero-weight skipping (the shipped design) and for the dense
//! baseline (skipping off) — without executing any arithmetic, so the
//! full-size 1024×576 network can be analyzed instantly. The same cost
//! constants drive the cycle counters of the executing
//! [`super::controller::SystemController`]; an integration test pins the
//! two models together on a small layer.

use super::controller::{CycleCosts, LayerInput};
use super::prosperity::ReuseForest;
use super::temporal::{plan_tile, ForestCache, MiningPlan};
use crate::config::{AccelConfig, ClusterConfig, Datapath, ShardPolicy};
use crate::coordinator::tiler::TilePlan;
use crate::model::topology::{ConvKind, ConvSpec, NetworkSpec};
use crate::model::weights::ModelWeights;
use crate::sparse::{SpikeMap, SpikePlane};

/// Per-layer latency result.
#[derive(Clone, Debug)]
pub struct LayerLatency {
    /// Layer name.
    pub name: String,
    /// Total work in cycles with weight skipping (summed over cores).
    pub sparse_cycles: u64,
    /// Total work without skipping.
    pub dense_cycles: u64,
    /// Layer makespan with weight skipping when the tile grid is sharded
    /// round-robin across `num_cores` cores: the busiest core carries
    /// `ceil(tiles / cores)` tiles, and every tile costs the same (cycle
    /// counts depend on weights, not activations). Equals `sparse_cycles`
    /// at `num_cores = 1`.
    pub sparse_makespan: u64,
    /// Dense-baseline makespan.
    pub dense_makespan: u64,
}

/// Whole-network latency result.
#[derive(Clone, Debug, Default)]
pub struct NetworkLatency {
    /// Per-layer records in execution order.
    pub layers: Vec<LayerLatency>,
}

impl NetworkLatency {
    /// Total work in cycles with weight skipping (summed over cores).
    pub fn sparse_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.sparse_cycles).sum()
    }

    /// Total dense-baseline cycles.
    pub fn dense_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.dense_cycles).sum()
    }

    /// Frame makespan: layers run back to back, each taking its
    /// multi-core makespan. Equals [`Self::sparse_cycles`] on one core.
    pub fn sparse_makespan(&self) -> u64 {
        self.layers.iter().map(|l| l.sparse_makespan).sum()
    }

    /// Dense-baseline frame makespan.
    pub fn dense_makespan(&self) -> u64 {
        self.layers.iter().map(|l| l.dense_makespan).sum()
    }

    /// Speedup of the configured core count over the same network's total
    /// single-core work (`1.0` at one core; ≤ `num_cores` always).
    pub fn core_speedup(&self) -> f64 {
        let m = self.sparse_makespan();
        if m == 0 {
            1.0
        } else {
            self.sparse_cycles() as f64 / m as f64
        }
    }

    /// Fraction of computing latency saved by zero-weight skipping
    /// (paper: 47.3%).
    pub fn latency_saving(&self) -> f64 {
        let d = self.dense_cycles();
        if d == 0 {
            0.0
        } else {
            1.0 - self.sparse_cycles() as f64 / d as f64
        }
    }

    /// Frames per second at `clock_hz` — per-frame latency is the
    /// multi-core makespan (identical to the total cycles on one core).
    pub fn fps(&self, clock_hz: f64) -> f64 {
        clock_hz / self.sparse_makespan() as f64
    }
}

/// The analytic model.
pub struct LatencyModel {
    cfg: AccelConfig,
    costs: CycleCosts,
}

impl LatencyModel {
    /// New model with default pipeline costs.
    pub fn new(cfg: AccelConfig) -> Self {
        LatencyModel { cfg, costs: CycleCosts::default() }
    }

    /// Cycles for one layer.
    ///
    /// Per tile, the KTBC loop costs
    /// `Σ_k [ conv_t · B · Σ_c (nnz(k,c) + input_switch) + out_t · lif_wb ]`
    /// plus the tile setup; `nnz → k²` for the dense baseline.
    pub fn layer(&self, spec: &ConvSpec, lw: &crate::model::weights::LayerWeights) -> LayerLatency {
        let tiles_x = spec.in_w.div_ceil(self.cfg.tile_w) as u64;
        let tiles_y = spec.in_h.div_ceil(self.cfg.tile_h) as u64;
        let n_tiles = tiles_x * tiles_y;
        let planes = if spec.kind == ConvKind::Encoding { 8u64 } else { 1 };
        let conv_t = spec.in_t as u64;
        let out_t = if spec.kind == ConvKind::Output { spec.in_t } else { spec.out_t } as u64;

        // Σ_c nnz(k,c) per output channel.
        let mut sparse_inner = 0u64;
        for k in 0..spec.c_out {
            for c in 0..spec.c_in {
                let plane = lw.w.plane(k, c);
                sparse_inner += plane.iter().filter(|&&w| w != 0).count() as u64;
            }
        }
        let dense_inner = (spec.c_out * spec.c_in * spec.k * spec.k) as u64;
        let switches = (spec.c_out * spec.c_in) as u64 * self.costs.input_switch;
        let lif = spec.c_out as u64 * out_t * self.costs.lif_writeback;

        // Mining charge (product-sparsity and temporal-delta datapaths):
        // stimulus-blind **upper bound** of `tile_h` cycles per extracted
        // `(t, b, c)` plane per tile. The executing controller charges the
        // mined forest's representative count (`patterns_unique ≤ th ≤
        // tile_h`), skips all-zero planes, and on the temporal path skips
        // cached/patched planes entirely, so the real charge is data
        // dependent — [`LatencyModel::layer_with_input`] reproduces it
        // exactly from the stimulus; this closed form bounds it from
        // above (DSE and fps sweeps keep using the bound). The dense
        // baseline never mines.
        let per_tile_mine = if self.cfg.datapath == Datapath::BitMask {
            0
        } else {
            conv_t * planes * spec.c_in as u64 * self.cfg.tile_h as u64
        };
        let per_tile_sparse = conv_t * planes * (sparse_inner + switches) + lif + per_tile_mine;
        let per_tile_dense = conv_t * planes * (dense_inner + switches) + lif;
        // Round-robin tile sharding: the busiest of the `num_cores` cores
        // carries ceil(tiles / cores) tiles — the executing controller's
        // schedule, reproduced in closed form.
        let busiest_tiles = n_tiles.div_ceil(self.cfg.num_cores.max(1) as u64);
        LayerLatency {
            name: spec.name.clone(),
            sparse_cycles: n_tiles * (per_tile_sparse + self.costs.tile_setup),
            dense_cycles: n_tiles * (per_tile_dense + self.costs.tile_setup),
            sparse_makespan: busiest_tiles * (per_tile_sparse + self.costs.tile_setup),
            dense_makespan: busiest_tiles * (per_tile_dense + self.costs.tile_setup),
        }
    }

    /// Stimulus-aware cycles for one layer: the closed-form uniform costs
    /// of [`LatencyModel::layer`] plus the **exact** data-dependent mining
    /// charge, derived by running the very same planner
    /// ([`super::temporal::plan_tile`]) the executing controller runs —
    /// same bit-slice prep, same tile extraction, same tile order, same
    /// shared pattern cache — so the per-core totals and the multi-core
    /// makespan are in lock-step with the executed counters by
    /// construction. On the bit-mask datapath this degenerates to
    /// [`LatencyModel::layer`] exactly.
    pub fn layer_with_input(
        &self,
        spec: &ConvSpec,
        lw: &crate::model::weights::LayerWeights,
        input: &LayerInput<'_>,
    ) -> LayerLatency {
        let planes = if spec.kind == ConvKind::Encoding { 8u64 } else { 1 };
        let conv_t = spec.in_t as u64;
        let out_t = if spec.kind == ConvKind::Output { spec.in_t } else { spec.out_t } as u64;
        let mut sparse_inner = 0u64;
        for k in 0..spec.c_out {
            for c in 0..spec.c_in {
                let plane = lw.w.plane(k, c);
                sparse_inner += plane.iter().filter(|&&w| w != 0).count() as u64;
            }
        }
        let dense_inner = (spec.c_out * spec.c_in * spec.k * spec.k) as u64;
        let switches = (spec.c_out * spec.c_in) as u64 * self.costs.input_switch;
        let lif = spec.c_out as u64 * out_t * self.costs.lif_writeback;
        let per_tile_base = conv_t * planes * (sparse_inner + switches) + lif;
        let per_tile_dense = conv_t * planes * (dense_inner + switches) + lif;

        // Stimulus prep, mirroring the controller: bit-slice pixel frames
        // (8 planes) or borrow the compressed spike maps directly.
        let owned_bits: Vec<Vec<SpikeMap>> = match input {
            LayerInput::Pixels(frames) => frames.iter().map(SpikeMap::bit_slice).collect(),
            LayerInput::Spikes(_) => Vec::new(),
        };
        let step_maps: Vec<Vec<&SpikeMap>> = match input {
            LayerInput::Pixels(_) => {
                owned_bits.iter().map(|bits| bits.iter().collect()).collect()
            }
            LayerInput::Spikes(maps) => maps.iter().map(|m| vec![m]).collect(),
        };
        let nb = step_maps.first().map(|bits| bits.len()).unwrap_or(0);
        let planes_per_step = nb * spec.c_in;
        let want_tiles = step_maps.len() * planes_per_step;

        let cores = self.cfg.num_cores.max(1);
        let mut core_sparse = vec![0u64; cores];
        let mut core_dense = vec![0u64; cores];
        // One cache for the whole layer, reset up front — the exact
        // lifecycle the controller gives its scratch cache.
        let mut cache = ForestCache::new(self.cfg.temporal_cache_planes);
        let mut tiles: Vec<SpikePlane> = Vec::new();
        let mut forests: Vec<ReuseForest> = Vec::new();
        let mut changed: Vec<bool> = Vec::new();
        let mut plan = MiningPlan::default();
        let grid = TilePlan::new(spec.in_w, spec.in_h, self.cfg.tile_w, self.cfg.tile_h);
        for (tile_idx, tile) in grid.iter().enumerate() {
            let mut mine = 0u64;
            if self.cfg.datapath != Datapath::BitMask {
                if tiles.len() < want_tiles {
                    tiles.resize_with(want_tiles, || SpikePlane::zeros(0, 0));
                    forests.resize_with(want_tiles, ReuseForest::default);
                }
                for (t, bit_maps) in step_maps.iter().enumerate() {
                    for (b, m) in bit_maps.iter().enumerate() {
                        for c in 0..spec.c_in {
                            m.plane(c).extract_tile_into(
                                tile.y0,
                                tile.x0,
                                tile.h,
                                tile.w,
                                &mut tiles[(t * nb + b) * spec.c_in + c],
                            );
                        }
                    }
                }
                plan_tile(
                    self.cfg.datapath,
                    &tiles[..want_tiles],
                    step_maps.len(),
                    planes_per_step,
                    spec.k,
                    &mut cache,
                    &mut forests,
                    &mut changed,
                    &mut plan,
                );
                mine = plan.mine_cycles;
            }
            let core = tile_idx % cores;
            core_sparse[core] += per_tile_base + self.costs.tile_setup + mine;
            core_dense[core] += per_tile_dense + self.costs.tile_setup;
        }
        LayerLatency {
            name: spec.name.clone(),
            sparse_cycles: core_sparse.iter().sum(),
            dense_cycles: core_dense.iter().sum(),
            sparse_makespan: core_sparse.iter().copied().max().unwrap_or(0),
            dense_makespan: core_dense.iter().copied().max().unwrap_or(0),
        }
    }

    /// Cycles for the whole network.
    pub fn network(&self, net: &NetworkSpec, weights: &ModelWeights) -> NetworkLatency {
        NetworkLatency {
            layers: net
                .layers
                .iter()
                .map(|l| self.layer(l, weights.get(&l.name).expect("weights cover net")))
                .collect(),
        }
    }

    /// Closed-form cluster compute model: what the multi-chip executor's
    /// per-chip cycle counters must add up to, per sharding policy,
    /// **before** interconnect time. The executing
    /// `crate::cluster::ChipCluster` uses this model's stage partition and
    /// must match its cycle totals exactly (cycle counts depend on
    /// weights, not activations — the same lock-step argument as the
    /// single-chip makespan). Interconnect time depends on activation
    /// popcounts, so it is recorded by the executor and re-priced from the
    /// transfer log with the same `LinkSpec` constants.
    pub fn cluster(net: &NetworkSpec, weights: &ModelWeights, cc: &ClusterConfig) -> ClusterLatency {
        let chips = cc.num_chips.max(1);
        match cc.policy {
            ShardPolicy::FrameParallel => {
                // Each frame runs whole on one chip.
                let lat = LatencyModel::new(cc.chip.clone()).network(net, weights);
                let makespan = lat.sparse_makespan();
                ClusterLatency {
                    policy: cc.policy,
                    num_chips: chips,
                    stage_layers: vec![(0..net.layers.len()).collect()],
                    stage_cycles: vec![makespan],
                    compute_makespan: makespan,
                }
            }
            ShardPolicy::LayerPipeline => {
                // Contiguous stages balanced by per-layer makespan; one
                // frame still visits every stage in sequence.
                let lat = LatencyModel::new(cc.chip.clone()).network(net, weights);
                let costs: Vec<u64> = lat.layers.iter().map(|l| l.sparse_makespan).collect();
                let stage_layers = partition_stages(&costs, chips);
                let stage_cycles: Vec<u64> = stage_layers
                    .iter()
                    .map(|layers| layers.iter().map(|&i| costs[i]).sum())
                    .collect();
                ClusterLatency {
                    policy: cc.policy,
                    num_chips: chips,
                    compute_makespan: stage_cycles.iter().sum(),
                    stage_layers,
                    stage_cycles,
                }
            }
            ShardPolicy::TileSplit => {
                // Every layer's tile grid is dealt round-robin across the
                // cluster's pooled cores — the existing multi-core makespan
                // formula at `chips × cores_per_chip` cores.
                let cores = cc.chip.num_cores.max(1) * chips;
                let lat =
                    LatencyModel::new(cc.chip.clone().with_cores(cores)).network(net, weights);
                let makespan = lat.sparse_makespan();
                ClusterLatency {
                    policy: cc.policy,
                    num_chips: chips,
                    stage_layers: vec![(0..net.layers.len()).collect()],
                    stage_cycles: vec![makespan],
                    compute_makespan: makespan,
                }
            }
        }
    }
}

/// Analytic cluster compute latency (no interconnect): per-policy stage
/// partition and cycle totals, in lock-step with the executing cluster's
/// counters.
#[derive(Clone, Debug)]
pub struct ClusterLatency {
    /// Sharding policy this was computed for.
    pub policy: ShardPolicy,
    /// Chips in the cluster.
    pub num_chips: usize,
    /// Layer indices per pipeline stage (`LayerPipeline`: one entry per
    /// chip, possibly empty when there are more chips than layers; other
    /// policies: a single entry listing every layer).
    pub stage_layers: Vec<Vec<usize>>,
    /// Compute cycles per stage (matching `stage_layers`).
    pub stage_cycles: Vec<u64>,
    /// Frame compute critical path in cycles: the cycles one frame spends
    /// computing, excluding interconnect transfers.
    pub compute_makespan: u64,
}

impl ClusterLatency {
    /// Steady-state initiation interval: with many frames in flight,
    /// `FrameParallel` starts a new frame every `makespan / chips` cycles
    /// (N chips run N frames concurrently), `LayerPipeline` every
    /// `max(stage_cycles)`, and `TileSplit` every frame makespan (all
    /// chips cooperate on one frame at a time).
    pub fn pipeline_interval(&self) -> u64 {
        match self.policy {
            ShardPolicy::FrameParallel => {
                self.compute_makespan.div_ceil(self.num_chips.max(1) as u64)
            }
            _ => self.stage_cycles.iter().copied().max().unwrap_or(0),
        }
    }

    /// Steady-state initiation interval achievable with at most
    /// `in_flight` frames resident: the unbounded interval
    /// ([`Self::pipeline_interval`]), floored by the residency window — a
    /// window of W frames cannot start frames faster than one per
    /// `compute_makespan / W` cycles, whatever the stage balance. At
    /// `in_flight = 1` this is the serial frame makespan; it converges to
    /// [`Self::pipeline_interval`] once the window covers the pipeline
    /// depth. The executing `ChipCluster::run_pipelined` must realize
    /// this interval within fill/drain + transfer slack (asserted in
    /// `tests/pipelined_cluster.rs` and `benches/perf_pipeline.rs`).
    pub fn pipeline_interval_bounded(&self, in_flight: usize) -> u64 {
        self.pipeline_interval().max(self.compute_makespan.div_ceil(in_flight.max(1) as u64))
    }
}

/// Partition `costs` (one entry per layer, execution order) into
/// `stages` contiguous groups balanced greedily against the ideal
/// `total / stages` target. Every layer lands in exactly one group; when
/// there are at least as many layers as stages every group is non-empty.
/// Deterministic — the executing cluster and the analytic model share it.
pub fn partition_stages(costs: &[u64], stages: usize) -> Vec<Vec<usize>> {
    let stages = stages.max(1);
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); stages];
    let total: u64 = costs.iter().sum();
    let target = (total / stages as u64).max(1);
    let mut s = 0usize;
    let mut acc = 0u64;
    for (i, &c) in costs.iter().enumerate() {
        // Layers still unplaced (including this one) and stages strictly
        // after the current one. Keeping layer `i` in stage `s` is only
        // allowed if enough layers remain to feed every later stage.
        let remaining_layers = costs.len() - i;
        let advance = s + 1 < stages
            && !out[s].is_empty()
            && (remaining_layers <= stages - s - 1 || acc + c > target);
        if advance {
            s += 1;
            acc = 0;
        }
        out[s].push(i);
        acc += c;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::controller::SystemController;
    use crate::model::topology::{Scale, TimeStepConfig};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn analytic_matches_executed_cycles() {
        // The executing controller and the analytic model must agree
        // exactly — they implement the same cost model.
        let spec = ConvSpec {
            name: "t".into(),
            kind: ConvKind::Spike,
            c_in: 3,
            c_out: 4,
            k: 3,
            in_t: 2,
            out_t: 2,
            maxpool_after: false,
            in_w: 16,
            in_h: 12,
            concat_with: None,
            input_from: None,
        };
        let net = NetworkSpec {
            name: "t".into(),
            input_w: 16,
            input_h: 12,
            input_c: 3,
            layers: vec![spec.clone()],
            num_anchors: 5,
            num_classes: 3,
        };
        let mut mw = ModelWeights::random(&net, 1.0, 7);
        mw.prune_fine_grained(0.7);
        let lw = mw.get("t").unwrap();

        let cfg = AccelConfig { tile_w: 8, tile_h: 6, ..AccelConfig::paper() };
        let analytic = LatencyModel::new(cfg.clone()).layer(&spec, lw);

        let mut rng = Rng::new(8);
        let inputs: Vec<crate::sparse::SpikeMap> = (0..2)
            .map(|_| {
                let n = 3 * 12 * 16;
                crate::sparse::SpikeMap::from_dense(&Tensor::from_vec(
                    3,
                    12,
                    16,
                    (0..n).map(|_| u8::from(rng.chance(0.3))).collect(),
                ))
            })
            .collect();
        let run = SystemController::new(cfg)
            .run_layer(&spec, lw, crate::accel::controller::LayerInput::Spikes(&inputs))
            .unwrap();
        assert_eq!(run.cycles, analytic.sparse_cycles);
        assert_eq!(run.dense_cycles, analytic.dense_cycles);
        assert_eq!(analytic.sparse_makespan, analytic.sparse_cycles, "one core: makespan = total");
    }

    #[test]
    fn multicore_makespan_in_lockstep_with_controller() {
        // The extended analytic model and the executing controller must
        // agree exactly on the multi-core layer makespan — including a
        // tile count (2×3 = 6 on a 16×18 map with 8×6 tiles) that does
        // not divide evenly by the core count.
        let spec = ConvSpec {
            name: "t".into(),
            kind: ConvKind::Spike,
            c_in: 3,
            c_out: 4,
            k: 3,
            in_t: 2,
            out_t: 2,
            maxpool_after: false,
            in_w: 16,
            in_h: 18,
            concat_with: None,
            input_from: None,
        };
        let net = NetworkSpec {
            name: "t".into(),
            input_w: 16,
            input_h: 18,
            input_c: 3,
            layers: vec![spec.clone()],
            num_anchors: 5,
            num_classes: 3,
        };
        let mut mw = ModelWeights::random(&net, 1.0, 12);
        mw.prune_fine_grained(0.7);
        let lw = mw.get("t").unwrap();
        let mut rng = Rng::new(13);
        let inputs: Vec<crate::sparse::SpikeMap> = (0..2)
            .map(|_| {
                let n = 3 * 18 * 16;
                crate::sparse::SpikeMap::from_dense(&Tensor::from_vec(
                    3,
                    18,
                    16,
                    (0..n).map(|_| u8::from(rng.chance(0.3))).collect(),
                ))
            })
            .collect();
        for cores in [1usize, 2, 3, 4, 6, 8] {
            let cfg =
                AccelConfig { tile_w: 8, tile_h: 6, ..AccelConfig::paper() }.with_cores(cores);
            let analytic = LatencyModel::new(cfg.clone()).layer(&spec, lw);
            let run = SystemController::new(cfg)
                .run_layer(&spec, lw, crate::accel::controller::LayerInput::Spikes(&inputs))
                .unwrap();
            assert_eq!(run.cycles, analytic.sparse_makespan, "cores={cores}");
            assert_eq!(run.dense_cycles, analytic.dense_makespan, "cores={cores}");
            assert_eq!(run.total_cycles(), analytic.sparse_cycles, "cores={cores}");
        }
    }

    #[test]
    fn stimulus_aware_model_in_lockstep_with_controller() {
        // The stimulus-aware model must match the executing controller's
        // counters exactly for every datapath — including the
        // data-dependent mining charge on clipped edge tiles (16×18 with
        // 8×6 tiles: the bottom row is clipped), temporally correlated
        // steps (step 1 = step 0 with one flipped pixel → patch planes)
        // and uneven core counts — while the dense baseline stays
        // untouched and the stimulus-blind closed form bounds the charge
        // from above.
        let spec = ConvSpec {
            name: "t".into(),
            kind: ConvKind::Spike,
            c_in: 3,
            c_out: 4,
            k: 3,
            in_t: 3,
            out_t: 3,
            maxpool_after: false,
            in_w: 16,
            in_h: 18,
            concat_with: None,
            input_from: None,
        };
        let net = NetworkSpec {
            name: "t".into(),
            input_w: 16,
            input_h: 18,
            input_c: 3,
            layers: vec![spec.clone()],
            num_anchors: 5,
            num_classes: 3,
        };
        let mut mw = ModelWeights::random(&net, 1.0, 51);
        mw.prune_fine_grained(0.7);
        let lw = mw.get("t").unwrap();
        let mut rng = Rng::new(52);
        let n = 3 * 18 * 16;
        let step0: Vec<u8> = (0..n).map(|_| u8::from(rng.chance(0.3))).collect();
        let mut step1 = step0.clone();
        step1[5 * 16 + 3] ^= 1; // one flipped pixel → mostly patched planes
        let step2: Vec<u8> = (0..n).map(|_| u8::from(rng.chance(0.3))).collect();
        let inputs: Vec<crate::sparse::SpikeMap> = [step0, step1, step2]
            .into_iter()
            .map(|d| crate::sparse::SpikeMap::from_dense(&Tensor::from_vec(3, 18, 16, d)))
            .collect();
        let base = AccelConfig { tile_w: 8, tile_h: 6, ..AccelConfig::paper() };
        for datapath in crate::config::Datapath::all() {
            for cores in [1usize, 2, 3, 4] {
                let cfg = base.clone().with_datapath(datapath).with_cores(cores);
                let model = LatencyModel::new(cfg.clone());
                let aware = model.layer_with_input(&spec, lw, &LayerInput::Spikes(&inputs));
                let blind = model.layer(&spec, lw);
                let run = SystemController::new(cfg)
                    .run_layer(&spec, lw, LayerInput::Spikes(&inputs))
                    .unwrap();
                assert_eq!(run.cycles, aware.sparse_makespan, "{datapath:?} cores={cores}");
                assert_eq!(run.dense_cycles, aware.dense_makespan, "{datapath:?} cores={cores}");
                assert_eq!(run.total_cycles(), aware.sparse_cycles, "{datapath:?} cores={cores}");
                assert_eq!(aware.dense_cycles, blind.dense_cycles, "{datapath:?} cores={cores}");
                assert!(
                    aware.sparse_cycles <= blind.sparse_cycles,
                    "{datapath:?} cores={cores}: blind model is an upper bound"
                );
                if datapath == Datapath::BitMask {
                    assert_eq!(aware.sparse_cycles, blind.sparse_cycles, "cores={cores}");
                    assert_eq!(aware.sparse_makespan, blind.sparse_makespan, "cores={cores}");
                }
            }
        }
        // The blind bound still separates the datapaths in the DSE grid:
        // mining-capable paths price strictly above the bit-mask path.
        let ps = LatencyModel::new(base.clone().with_datapath(Datapath::Prosperity))
            .layer(&spec, lw);
        let bm = LatencyModel::new(base).layer(&spec, lw);
        assert!(ps.sparse_cycles > bm.sparse_cycles);
    }

    #[test]
    fn stimulus_aware_model_handles_encoding_bit_planes() {
        // Encoding layers bit-slice the stimulus into 8 planes; the
        // stimulus-aware model must reproduce the controller's mining
        // charge over all of them (Pixels input path).
        let spec = ConvSpec {
            name: "enc".into(),
            kind: ConvKind::Encoding,
            c_in: 3,
            c_out: 4,
            k: 3,
            in_t: 1,
            out_t: 1,
            maxpool_after: false,
            in_w: 16,
            in_h: 12,
            concat_with: None,
            input_from: None,
        };
        let net = NetworkSpec {
            name: "enc".into(),
            input_w: 16,
            input_h: 12,
            input_c: 3,
            layers: vec![spec.clone()],
            num_anchors: 5,
            num_classes: 3,
        };
        let mut mw = ModelWeights::random(&net, 1.0, 61);
        mw.prune_fine_grained(0.5);
        let lw = mw.get("enc").unwrap();
        let mut rng = Rng::new(62);
        let n = 3 * 12 * 16;
        let frames = vec![Tensor::from_vec(
            3,
            12,
            16,
            (0..n).map(|_| rng.next_u32() as u8).collect::<Vec<u8>>(),
        )];
        let base = AccelConfig { tile_w: 8, tile_h: 6, ..AccelConfig::paper() };
        for datapath in [Datapath::Prosperity, Datapath::TemporalDelta] {
            let cfg = base.clone().with_datapath(datapath);
            let aware = LatencyModel::new(cfg.clone())
                .layer_with_input(&spec, lw, &LayerInput::Pixels(&frames));
            let run = SystemController::new(cfg)
                .run_layer(&spec, lw, LayerInput::Pixels(&frames))
                .unwrap();
            assert_eq!(run.cycles, aware.sparse_makespan, "{datapath:?}");
            assert_eq!(run.total_cycles(), aware.sparse_cycles, "{datapath:?}");
            assert_eq!(run.dense_cycles, aware.dense_makespan, "{datapath:?}");
        }
    }

    #[test]
    fn core_speedup_saturates_at_tile_count() {
        // A layer with 6 tiles cannot speed up past 6×, and speedup is
        // monotone in the core count.
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 14);
        mw.prune_fine_grained(0.8);
        let mut prev = 0.0f64;
        for cores in [1usize, 2, 4, 8, 16] {
            let lat =
                LatencyModel::new(AccelConfig::paper().with_cores(cores)).network(&net, &mw);
            let s = lat.core_speedup();
            assert!(s >= prev, "cores={cores}: speedup regressed {s} < {prev}");
            assert!(s <= cores as f64 + 1e-9, "cores={cores}: superlinear {s}");
            prev = s;
        }
    }

    #[test]
    fn paper_pruning_gives_paper_scale_saving() {
        // §IV-E: zero-weight skipping saves ~47.3% of computing latency at
        // the paper's pruning rate.
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 9);
        mw.prune_fine_grained(0.8);
        let lat = LatencyModel::new(AccelConfig::paper()).network(&net, &mw);
        let saving = lat.latency_saving();
        assert!((0.30..0.70).contains(&saving), "saving={saving}");
    }

    #[test]
    fn full_network_fps_near_paper() {
        // Paper: 29 fps at 500 MHz for 1024×576. Our geometry differs in
        // detail; require the same order of magnitude.
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 10);
        mw.prune_fine_grained(0.8);
        let lat = LatencyModel::new(AccelConfig::paper()).network(&net, &mw);
        let fps = lat.fps(500e6);
        assert!((5.0..120.0).contains(&fps), "fps={fps}");
    }

    #[test]
    fn partition_stages_is_contiguous_and_total() {
        for (costs, stages) in [
            (vec![1u64, 1, 1, 1, 1], 2usize),
            (vec![10, 1, 1], 3),
            (vec![1, 1, 100], 2),
            (vec![5, 5], 2),
            (vec![7], 4),
            (vec![3, 9, 2, 8, 4, 6, 1, 5], 3),
        ] {
            let parts = partition_stages(&costs, stages);
            assert_eq!(parts.len(), stages, "{costs:?}");
            let flat: Vec<usize> = parts.iter().flatten().copied().collect();
            assert_eq!(flat, (0..costs.len()).collect::<Vec<_>>(), "{costs:?}: contiguous order");
            if costs.len() >= stages {
                assert!(parts.iter().all(|p| !p.is_empty()), "{costs:?}: no starved stage");
            }
        }
    }

    #[test]
    fn cluster_compute_model_per_policy() {
        use crate::config::{ClusterConfig, ShardPolicy};
        let net = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 21);
        mw.prune_fine_grained(0.8);
        let single = LatencyModel::new(AccelConfig::paper()).network(&net, &mw);

        let cc = ClusterConfig::single_chip().with_chips(3);
        // Frame-parallel: per-frame latency is the single-chip makespan.
        let fp = LatencyModel::cluster(&net, &mw, &cc.clone().with_policy(ShardPolicy::FrameParallel));
        assert_eq!(fp.compute_makespan, single.sparse_makespan());
        // Layer-pipeline: stages cover every layer once; one frame still
        // computes the same total, and the initiation interval shrinks.
        let lp = LatencyModel::cluster(&net, &mw, &cc.clone().with_policy(ShardPolicy::LayerPipeline));
        assert_eq!(lp.stage_layers.len(), 3);
        let flat: Vec<usize> = lp.stage_layers.iter().flatten().copied().collect();
        assert_eq!(flat, (0..net.layers.len()).collect::<Vec<_>>());
        assert_eq!(lp.compute_makespan, single.sparse_makespan());
        assert!(lp.pipeline_interval() < lp.compute_makespan);
        // Tile-split: pooled cores shrink the frame compute critical path.
        let ts = LatencyModel::cluster(&net, &mw, &cc.clone().with_policy(ShardPolicy::TileSplit));
        assert!(ts.compute_makespan < single.sparse_makespan());
        assert_eq!(
            ts.compute_makespan,
            LatencyModel::new(AccelConfig::paper().with_cores(3)).network(&net, &mw).sparse_makespan()
        );
        // One chip: every policy degenerates to the single-chip makespan.
        for p in ShardPolicy::all() {
            let one = LatencyModel::cluster(&net, &mw, &ClusterConfig::single_chip().with_policy(p));
            assert_eq!(one.compute_makespan, single.sparse_makespan(), "{p:?}");
        }
    }

    #[test]
    fn bounded_interval_interpolates_serial_to_steady() {
        use crate::config::{ClusterConfig, ShardPolicy};
        let net = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 23);
        mw.prune_fine_grained(0.8);
        for policy in ShardPolicy::all() {
            let cc = ClusterConfig::single_chip().with_chips(3).with_policy(policy);
            let cl = LatencyModel::cluster(&net, &mw, &cc);
            // One frame in flight = strictly serial: the frame makespan.
            assert_eq!(cl.pipeline_interval_bounded(1), cl.compute_makespan, "{policy:?}");
            // A deep window converges to the unbounded steady state.
            assert_eq!(cl.pipeline_interval_bounded(64), cl.pipeline_interval(), "{policy:?}");
            // Monotone non-increasing in the window size.
            let mut prev = u64::MAX;
            for w in 1..=8 {
                let i = cl.pipeline_interval_bounded(w);
                assert!(i <= prev, "{policy:?} w={w}: {i} > {prev}");
                prev = i;
            }
        }
    }

    #[test]
    fn mixed_time_steps_cut_cycles() {
        let mw_of = |ts| {
            let net = NetworkSpec::paper(Scale::Full, ts);
            let mut mw = ModelWeights::random(&net, 1.0, 11);
            mw.prune_fine_grained(0.8);
            (net, mw)
        };
        let (n3, w3) = mw_of(TimeStepConfig::Uniform(3));
        let (nc2, wc2) = mw_of(TimeStepConfig::C2(3));
        let m = LatencyModel::new(AccelConfig::paper());
        assert!(m.network(&nc2, &wc2).sparse_cycles() < m.network(&n3, &w3).sparse_cycles());
    }
}
