//! On-chip SRAM bank model (Fig 7): capacity checking plus access
//! counting for the power model.
//!
//! The chip has four kinds of banks — Input (×4, 144-bit wide, one per
//! spatial sub-tile), Output (×4), Weight Map, and NZ Weight — totalling
//! 288.5 KB. Input memory dominates memory power (73%, Fig 18b) because
//! all four banks are read simultaneously whenever the input channel
//! advances; the model reproduces that directly from access counts and
//! per-access energy proportional to word width.

use anyhow::{bail, Result};

/// Bank role (fixes word width and energy coefficients).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SramKind {
    /// Input activation banks (paper: 4 × 9 KB, 144-bit words).
    Input,
    /// Output activation banks (paper: 4 × 9 KB).
    Output,
    /// Weight bit-mask bank.
    WeightMap,
    /// Nonzero weight values bank.
    NzWeight,
}

impl SramKind {
    /// Word width in bits.
    pub fn word_bits(self) -> usize {
        match self {
            // 4 banks × 144 bit = 576 spike bits: one bit per PE.
            SramKind::Input | SramKind::Output => 144,
            // One 3×3 bit mask word per access.
            SramKind::WeightMap => 16,
            // Two 8-bit weights per access (64-bit words packed).
            SramKind::NzWeight => 64,
        }
    }

    /// Read energy per access in pJ. Derived from 28nm SRAM macro
    /// characteristics (~0.1–0.2 pJ/bit read for small macros) — calibrated
    /// so the SNN-d workload reproduces Fig 18's memory-power share (48%
    /// of a ~30 mW core, with input banks ≈ 73% of memory power).
    pub fn read_pj(self) -> f64 {
        self.word_bits() as f64 * 0.14
    }

    /// Write energy per access in pJ (writes cost slightly more).
    pub fn write_pj(self) -> f64 {
        self.word_bits() as f64 * 0.17
    }
}

/// One SRAM bank with capacity + access accounting.
#[derive(Clone, Debug)]
pub struct SramBank {
    /// Role.
    pub kind: SramKind,
    /// Capacity in bytes.
    pub capacity_bytes: usize,
    /// Current allocation in bytes (checked against capacity).
    used_bytes: usize,
    /// Read accesses.
    pub reads: u64,
    /// Write accesses.
    pub writes: u64,
}

impl SramBank {
    /// New empty bank.
    pub fn new(kind: SramKind, capacity_bytes: usize) -> Self {
        SramBank { kind, capacity_bytes, used_bytes: 0, reads: 0, writes: 0 }
    }

    /// Reserve `bytes` (a layer's working set); errors if it exceeds the
    /// capacity — the condition that forces DRAM refetch in §IV-D.
    pub fn alloc(&mut self, bytes: usize) -> Result<()> {
        if self.used_bytes + bytes > self.capacity_bytes {
            bail!(
                "{:?} SRAM overflow: {} + {} > {}",
                self.kind, self.used_bytes, bytes, self.capacity_bytes
            );
        }
        self.used_bytes += bytes;
        Ok(())
    }

    /// Whether `bytes` fits from scratch.
    pub fn fits(&self, bytes: usize) -> bool {
        bytes <= self.capacity_bytes
    }

    /// Release the allocation (next layer).
    pub fn free(&mut self) {
        self.used_bytes = 0;
    }

    /// Count `n` read accesses.
    pub fn read(&mut self, n: u64) {
        self.reads += n;
    }

    /// Count `n` write accesses.
    pub fn write(&mut self, n: u64) {
        self.writes += n;
    }

    /// Energy consumed so far in pJ.
    pub fn energy_pj(&self) -> f64 {
        self.reads as f64 * self.kind.read_pj() + self.writes as f64 * self.kind.write_pj()
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> usize {
        self.used_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_enforced() {
        let mut b = SramBank::new(SramKind::Input, 1024);
        b.alloc(1000).unwrap();
        assert!(b.alloc(100).is_err());
        b.free();
        assert!(b.alloc(1024).is_ok());
    }

    #[test]
    fn energy_accumulates() {
        let mut b = SramBank::new(SramKind::WeightMap, 1024);
        b.read(10);
        b.write(5);
        let want = 10.0 * SramKind::WeightMap.read_pj() + 5.0 * SramKind::WeightMap.write_pj();
        assert!((b.energy_pj() - want).abs() < 1e-9);
    }

    #[test]
    fn input_words_match_pe_count() {
        // 4 input banks × 144-bit words = 576 bits = one bit per PE.
        assert_eq!(4 * SramKind::Input.word_bits(), 576);
    }

    #[test]
    fn fits_is_pure() {
        let b = SramBank::new(SramKind::NzWeight, 100);
        assert!(b.fits(100));
        assert!(!b.fits(101));
    }
}
