//! Design-parallelism analysis (§III-A, Fig 6).
//!
//! The paper allocates 576 PEs three ways and compares latency:
//!
//! 1. **Input-channel parallelism** `(p, h, w)`: `p` lanes each stream a
//!    different input channel's compressed weights. Because pruned
//!    channels have different nonzero counts the lanes imbalance; FIFOs of
//!    depth `d` decouple them (lane may run at most `d` channel-batches
//!    ahead of the slowest lane). `d = 0` is a hard barrier per batch;
//!    `d → ∞` approaches the max-of-sums lower bound, at the cost of FIFO
//!    area that can exceed the PEs themselves.
//! 2. **Output-channel parallelism** `(p, h, w)` sharing one input sweep:
//!    every input channel costs the *max* nonzero count over the `p`
//!    output channels in the group, and the input cannot advance early.
//! 3. **Spatial parallelism** `(0, 18, 32)` — the paper's choice: all PEs
//!    process the same weight stream on different pixels, so there is no
//!    imbalance at all; latency is exactly the nonzero count.

use super::latency::LatencyModel;
use crate::config::AccelConfig;
use crate::model::topology::NetworkSpec;
use crate::model::weights::ModelWeights;

/// A layer's sparse workload: nonzero count per `(k, c)` kernel plane.
#[derive(Clone, Debug)]
pub struct LayerWorkload {
    /// `nnz[k][c]`.
    pub nnz: Vec<Vec<u32>>,
    /// Feature width/height this layer processes.
    pub in_w: usize,
    /// Feature height.
    pub in_h: usize,
    /// Executed conv passes (time steps × bit planes).
    pub passes: u64,
}

impl LayerWorkload {
    /// Extract workloads for a whole network.
    pub fn from_model(net: &NetworkSpec, weights: &ModelWeights) -> Vec<LayerWorkload> {
        net.layers
            .iter()
            .map(|l| {
                let lw = weights.get(&l.name).expect("weights cover net");
                let nnz = (0..l.c_out)
                    .map(|k| {
                        (0..l.c_in)
                            .map(|c| {
                                lw.w.plane(k, c).iter().filter(|&&w| w != 0).count() as u32
                            })
                            .collect()
                    })
                    .collect();
                let planes = if l.kind == crate::model::topology::ConvKind::Encoding {
                    8
                } else {
                    1
                } as u64;
                LayerWorkload {
                    nnz,
                    in_w: l.in_w,
                    in_h: l.in_h,
                    passes: l.in_t as u64 * planes,
                }
            })
            .collect()
    }
}

/// PE organization (input-parallel lanes, PE-region height, width).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PeOrg {
    /// Parallel lanes along the channel dimension (0 = spatial-only).
    pub p: usize,
    /// Spatial region height covered per step.
    pub h: usize,
    /// Spatial region width covered per step.
    pub w: usize,
}

impl PeOrg {
    /// The paper's spatial organization.
    pub const SPATIAL: PeOrg = PeOrg { p: 0, h: 18, w: 32 };

    /// Total PEs used.
    pub fn pes(&self) -> usize {
        self.p.max(1) * self.h * self.w
    }

    /// Spatial iterations needed to cover a `w × h` feature map.
    fn tile_iters(&self, in_w: usize, in_h: usize) -> u64 {
        (in_w.div_ceil(self.w) as u64) * (in_h.div_ceil(self.h) as u64)
    }
}

/// Latency (cycles) of one layer under **spatial** parallelism: one cycle
/// per nonzero weight, no imbalance.
pub fn spatial_latency(wl: &LayerWorkload, org: PeOrg) -> u64 {
    let inner: u64 = wl.nnz.iter().flatten().map(|&n| n as u64).sum();
    inner * wl.passes * org.tile_iters(wl.in_w, wl.in_h)
}

/// Latency of one layer under **input-channel** parallelism with a
/// decoupling FIFO of `depth` channel-batches per lane.
///
/// Channels are dealt round-robin to the `p` lanes in batches; lane `l`
/// may begin batch `j` only after every lane has finished batch
/// `j - depth` (the window the FIFOs can absorb).
pub fn input_parallel_latency(wl: &LayerWorkload, org: PeOrg, depth: usize) -> u64 {
    assert!(org.p >= 1);
    let iters = org.tile_iters(wl.in_w, wl.in_h) * wl.passes;
    let mut total = 0u64;
    for k_nnz in &wl.nnz {
        let batches = k_nnz.len().div_ceil(org.p);
        // finish[l] per batch; barrier[j] = max_l finish at batch j.
        let mut lane_t = vec![0u64; org.p];
        let mut barrier: Vec<u64> = Vec::with_capacity(batches);
        for j in 0..batches {
            let window_floor = if j > depth { barrier[j - depth - 1] } else { 0 };
            let mut bmax = 0u64;
            for (l, t) in lane_t.iter_mut().enumerate() {
                let c = j * org.p + l;
                let work = k_nnz.get(c).copied().unwrap_or(0) as u64;
                *t = (*t).max(window_floor) + work;
                bmax = bmax.max(*t);
            }
            barrier.push(bmax);
        }
        total += *barrier.last().unwrap_or(&0);
    }
    total * iters
}

/// Latency of one layer under **output-channel** parallelism: `p` output
/// channels share one input sweep; each input channel costs the max
/// nonzero count in the group, and the group is a barrier.
pub fn output_parallel_latency(wl: &LayerWorkload, org: PeOrg) -> u64 {
    assert!(org.p >= 1);
    let iters = org.tile_iters(wl.in_w, wl.in_h) * wl.passes;
    let num_k = wl.nnz.len();
    let num_c = wl.nnz.first().map(|r| r.len()).unwrap_or(0);
    let mut total = 0u64;
    let mut k0 = 0;
    while k0 < num_k {
        let k1 = (k0 + org.p).min(num_k);
        for c in 0..num_c {
            let mx = (k0..k1).map(|k| wl.nnz[k][c] as u64).max().unwrap_or(0);
            total += mx;
        }
        k0 = k1;
    }
    total * iters
}

/// Estimated FIFO storage for input parallelism: each of the `p` lanes
/// buffers up to `depth` batches of 16-bit partial sums for its `h × w`
/// region.
pub fn fifo_bytes(org: PeOrg, depth: usize) -> usize {
    org.p * depth * org.h * org.w * 2
}

/// One row of the multi-core scaling study: replicating whole PE cores
/// (the fourth axis beyond Fig 6's three intra-core organizations).
#[derive(Clone, Debug)]
pub struct MulticoreRow {
    /// Core count.
    pub cores: usize,
    /// Network makespan in cycles (analytic, weight skipping on).
    pub makespan: u64,
    /// Speedup over one core.
    pub speedup: f64,
    /// Parallel efficiency (`speedup / cores`).
    pub efficiency: f64,
}

/// Analytic multi-core scaling of a network: tile-grid sharding across
/// replicated spatial-parallel cores, per the extended
/// [`LatencyModel`]. Speedup saturates at the smallest layer's tile count
/// (the head runs on one core no matter how many exist) — the Amdahl
/// ceiling the Fig 6 cross-check reports.
pub fn multicore_study(
    net: &NetworkSpec,
    weights: &ModelWeights,
    cfg: &AccelConfig,
    core_counts: &[usize],
) -> Vec<MulticoreRow> {
    let base = LatencyModel::new(cfg.clone().with_cores(1))
        .network(net, weights)
        .sparse_makespan();
    core_counts
        .iter()
        .map(|&cores| {
            let makespan = LatencyModel::new(cfg.clone().with_cores(cores))
                .network(net, weights)
                .sparse_makespan();
            let speedup = if makespan == 0 { 1.0 } else { base as f64 / makespan as f64 };
            MulticoreRow { cores, makespan, speedup, efficiency: speedup / cores.max(1) as f64 }
        })
        .collect()
}

/// One row of the Fig 6 study.
#[derive(Clone, Debug)]
pub struct ParallelismRow {
    /// Organization label, e.g. `(8,9,8)`.
    pub label: String,
    /// FIFO depth (input parallelism only).
    pub fifo_depth: usize,
    /// Total network latency in cycles.
    pub cycles: u64,
    /// Latency relative to spatial parallelism.
    pub rel_latency: f64,
    /// FIFO storage cost in bytes.
    pub fifo_bytes: usize,
}

/// Run the full Fig 6 study over a network.
pub fn fig6_study(net: &NetworkSpec, weights: &ModelWeights) -> Vec<ParallelismRow> {
    let wls = LayerWorkload::from_model(net, weights);
    let spatial: u64 = wls.iter().map(|w| spatial_latency(w, PeOrg::SPATIAL)).sum();
    let mut rows = vec![ParallelismRow {
        label: "(0,18,32) spatial".into(),
        fifo_depth: 0,
        cycles: spatial,
        rel_latency: 1.0,
        fifo_bytes: 0,
    }];
    // Fig 6(a): input parallelism (8,9,8) across FIFO depths.
    let in_org = PeOrg { p: 8, h: 9, w: 8 };
    for depth in [0usize, 1, 2, 4, 8, 16, 32] {
        let cycles: u64 = wls.iter().map(|w| input_parallel_latency(w, in_org, depth)).sum();
        rows.push(ParallelismRow {
            label: "(8,9,8) input".into(),
            fifo_depth: depth,
            cycles,
            rel_latency: cycles as f64 / spatial as f64,
            fifo_bytes: fifo_bytes(in_org, depth),
        });
    }
    // Fig 6(b): output parallelism at several organizations.
    for (p, h, w) in [(2usize, 18usize, 16usize), (4, 9, 16), (8, 9, 8), (16, 6, 6)] {
        let org = PeOrg { p, h, w };
        let cycles: u64 = wls.iter().map(|wl| output_parallel_latency(wl, org)).sum();
        rows.push(ParallelismRow {
            label: format!("({p},{h},{w}) output"),
            fifo_depth: 0,
            cycles,
            rel_latency: cycles as f64 / spatial as f64,
            fifo_bytes: 0,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::topology::{Scale, TimeStepConfig};
    use crate::util::propcheck::run_prop;

    fn workload(seed: u64) -> LayerWorkload {
        let mut rng = crate::util::Rng::new(seed);
        let nnz = (0..8)
            .map(|_| (0..16).map(|_| rng.below(10) as u32).collect())
            .collect();
        LayerWorkload { nnz, in_w: 32, in_h: 18, passes: 1 }
    }

    #[test]
    fn spatial_is_sum_of_nnz() {
        let wl = workload(1);
        let want: u64 = wl.nnz.iter().flatten().map(|&n| n as u64).sum();
        assert_eq!(spatial_latency(&wl, PeOrg::SPATIAL), want);
    }

    #[test]
    fn input_parallel_never_beats_per_lane_sum_bound() {
        run_prop("parallelism/input-bounds", |g| {
            let wl = workload(g.rng().next_u64());
            let org = PeOrg { p: 8, h: 9, w: 8 };
            // More spatial iterations for the smaller region:
            let iters = 4u64; // 32×18 / (9×8) → 4 iterations
            let barrier = input_parallel_latency(&wl, org, 0);
            let deep = input_parallel_latency(&wl, org, 64);
            // Deeper FIFOs can only help.
            assert!(deep <= barrier, "deep={deep} barrier={barrier}");
            // Lower bound: busiest lane, summed per k.
            let mut lb = 0u64;
            for k_nnz in &wl.nnz {
                let mut lane = vec![0u64; org.p];
                for (c, &n) in k_nnz.iter().enumerate() {
                    lane[c % org.p] += n as u64;
                }
                lb += lane.iter().copied().max().unwrap();
            }
            assert!(deep >= lb * iters, "deep={deep} lb={}", lb * iters);
        });
    }

    #[test]
    fn fifo_depth_monotone() {
        let wl = workload(3);
        let org = PeOrg { p: 8, h: 9, w: 8 };
        let mut prev = u64::MAX;
        for d in [0, 1, 2, 4, 8, 16] {
            let c = input_parallel_latency(&wl, org, d);
            assert!(c <= prev, "depth {d}: {c} > {prev}");
            prev = c;
        }
    }

    #[test]
    fn output_parallel_pays_max_per_group() {
        // Two output channels with very different nnz: the group costs
        // the max, so half the PEs idle.
        let wl = LayerWorkload {
            nnz: vec![vec![9, 9], vec![1, 1]],
            in_w: 32,
            in_h: 18,
            passes: 1,
        };
        let org = PeOrg { p: 2, h: 18, w: 16 };
        // groups: {k0,k1}; per c: max(9,1)=9; total = 18 × 2 iters... —
        // 32×18 with (18,16) region → 2 iterations.
        assert_eq!(output_parallel_latency(&wl, org), 18 * 2);
        // Spatial: (9+9+1+1) = 20 cycles, 1 iteration.
        assert_eq!(spatial_latency(&wl, PeOrg::SPATIAL), 20);
    }

    #[test]
    fn multicore_study_shape() {
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 6);
        mw.prune_fine_grained(0.8);
        let rows = multicore_study(&net, &mw, &AccelConfig::paper(), &[1, 2, 4, 8]);
        assert_eq!(rows[0].cores, 1);
        assert!((rows[0].speedup - 1.0).abs() < 1e-12);
        for pair in rows.windows(2) {
            assert!(pair[1].speedup >= pair[0].speedup, "speedup must be monotone");
        }
        for r in &rows {
            assert!(r.speedup <= r.cores as f64 + 1e-9, "no superlinear scaling");
            assert!(r.efficiency > 0.0 && r.efficiency <= 1.0 + 1e-9);
        }
        // Early full-size layers have hundreds of tiles, so 8 cores beat
        // 2× easily — but the deep layers (b3/b4: ≤ 4 tiles) serialize,
        // so the scaling is distinctly sublinear (Amdahl on tile count).
        let s8 = rows.last().unwrap().speedup;
        assert!(s8 > 2.0, "8-core speedup {s8}");
        assert!(s8 < 8.0, "8-core speedup {s8} should hit the small-layer ceiling");
    }

    #[test]
    fn fig6_shape_on_pruned_network() {
        // The headline of Fig 6: both channel parallelisms are slower than
        // spatial on the pruned network, and input parallelism approaches
        // (but does not beat) spatial as FIFO depth grows. Run at full
        // scale — the comparison only holds when every feature map is at
        // least one PE region (§III-A: "the only restriction is that the
        // input size be large enough").
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 4);
        mw.prune_fine_grained(0.8);
        let rows = fig6_study(&net, &mw);
        let spatial = rows[0].cycles;
        for r in &rows[1..] {
            assert!(
                r.cycles >= spatial,
                "{} d={} is faster than spatial: {} < {spatial}",
                r.label, r.fifo_depth, r.cycles,
            );
        }
        // Deep-FIFO input parallelism within 2× of spatial; barrier (d=0)
        // strictly worse than d=32.
        let d0 = rows.iter().find(|r| r.label.contains("input") && r.fifo_depth == 0).unwrap();
        let d32 = rows.iter().find(|r| r.fifo_depth == 32).unwrap();
        assert!(d32.cycles <= d0.cycles);
        // FIFO bytes grow with depth.
        assert!(d32.fifo_bytes > 0);
    }
}
