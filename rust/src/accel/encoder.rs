//! Row/column priority encoders (Fig 11).
//!
//! Each cycle the PE module consumes one nonzero weight: the encoders find
//! the **leftmost nonzero bit** of the weight map (row-major scan), emit
//! its `(row, col)` position — which selects the enable-map shift — and the
//! bit is cleared before the next cycle. When the map reaches zero the
//! plane is done and the controller advances the `C` loop.

/// Combinational priority encoder over a ≤16-bit weight map word.
#[derive(Clone, Debug)]
pub struct PriorityEncoder {
    map: u16,
    kw: usize,
}

impl PriorityEncoder {
    /// Load a weight map for a `kh × kw` plane.
    pub fn load(map: u16, kw: usize) -> Self {
        assert!(kw > 0);
        PriorityEncoder { map, kw }
    }

    /// Whether any nonzero weight remains.
    pub fn has_next(&self) -> bool {
        self.map != 0
    }

    /// Pop the position of the leftmost (lowest-index) nonzero bit as
    /// `(row, col)`, clearing it — one hardware cycle.
    pub fn next_position(&mut self) -> Option<(usize, usize)> {
        if self.map == 0 {
            return None;
        }
        let i = self.map.trailing_zeros() as usize;
        self.map &= self.map - 1; // clear lowest set bit
        Some((i / self.kw, i % self.kw))
    }

    /// Remaining nonzero count (= remaining cycles for this plane).
    pub fn remaining(&self) -> usize {
        self.map.count_ones() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::BitMaskKernel;
    use crate::util::propcheck::run_prop;

    #[test]
    fn scans_row_major() {
        // Map for a 3×3 plane with bits at (0,1), (1,2), (2,0).
        let map = (1 << 1) | (1 << 5) | (1 << 6);
        let mut e = PriorityEncoder::load(map, 3);
        assert_eq!(e.remaining(), 3);
        assert_eq!(e.next_position(), Some((0, 1)));
        assert_eq!(e.next_position(), Some((1, 2)));
        assert_eq!(e.next_position(), Some((2, 0)));
        assert_eq!(e.next_position(), None);
        assert!(!e.has_next());
    }

    #[test]
    fn empty_map() {
        let mut e = PriorityEncoder::load(0, 3);
        assert!(!e.has_next());
        assert_eq!(e.next_position(), None);
    }

    #[test]
    fn prop_matches_bitmask_iteration() {
        // The encoder must visit exactly the positions of the bit-mask
        // representation, in the same order.
        run_prop("encoder/matches-bitmask", |g| {
            let plane = g.sparse_i8(9, 0.4);
            let bm = BitMaskKernel::from_dense(&plane, 3, 3);
            let mut e = PriorityEncoder::load(bm.map[0], 3);
            for (r, c, _w) in bm.iter_nz() {
                assert_eq!(e.next_position(), Some((r, c)));
            }
            assert_eq!(e.next_position(), None);
        });
    }
}
