//! Row/column priority encoders (Fig 11).
//!
//! Each cycle the PE module consumes one nonzero weight: the encoders find
//! the **leftmost nonzero bit** of the weight map (row-major scan), emit
//! its `(row, col)` position — which selects the enable-map shift — and the
//! bit is cleared before the next cycle. When the map reaches zero the
//! plane is done and the controller advances the `C` loop.
//!
//! A 3×3 plane fits one 16-bit map word and takes the combinational
//! single-word fast path; 5×5 and 7×7 planes span multiple words, scanned
//! in order with exhausted words skipped in O(1).

/// Priority encoder over a multi-word weight map (16 positions per word).
#[derive(Clone, Debug)]
pub struct PriorityEncoder {
    words: Vec<u16>,
    /// Index of the first possibly-nonzero word.
    cursor: usize,
    kw: usize,
}

impl PriorityEncoder {
    /// Load a single-word map for a `kh × kw` plane (`kh*kw ≤ 16` — the
    /// 3×3 fast path, and the signature the RTL-sized tests use).
    pub fn load(map: u16, kw: usize) -> Self {
        Self::load_words(&[map], kw)
    }

    /// Load a multi-word map (row-major, LSB-first within each word).
    pub fn load_words(map: &[u16], kw: usize) -> Self {
        assert!(kw > 0);
        assert!(!map.is_empty());
        PriorityEncoder { words: map.to_vec(), cursor: 0, kw }
    }

    /// Whether any nonzero weight remains.
    pub fn has_next(&self) -> bool {
        self.words[self.cursor..].iter().any(|&w| w != 0)
    }

    /// Pop the position of the leftmost (lowest-index) nonzero bit as
    /// `(row, col)`, clearing it — one hardware cycle.
    pub fn next_position(&mut self) -> Option<(usize, usize)> {
        while self.cursor < self.words.len() {
            let word = self.words[self.cursor];
            if word == 0 {
                self.cursor += 1;
                continue;
            }
            let bit = word.trailing_zeros() as usize;
            self.words[self.cursor] &= word - 1; // clear lowest set bit
            let i = self.cursor * 16 + bit;
            return Some((i / self.kw, i % self.kw));
        }
        None
    }

    /// Remaining nonzero count (= remaining cycles for this plane).
    pub fn remaining(&self) -> usize {
        self.words[self.cursor..].iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::BitMaskKernel;
    use crate::util::propcheck::run_prop;

    #[test]
    fn scans_row_major() {
        // Map for a 3×3 plane with bits at (0,1), (1,2), (2,0).
        let map = (1 << 1) | (1 << 5) | (1 << 6);
        let mut e = PriorityEncoder::load(map, 3);
        assert_eq!(e.remaining(), 3);
        assert_eq!(e.next_position(), Some((0, 1)));
        assert_eq!(e.next_position(), Some((1, 2)));
        assert_eq!(e.next_position(), Some((2, 0)));
        assert_eq!(e.next_position(), None);
        assert!(!e.has_next());
    }

    #[test]
    fn empty_map() {
        let mut e = PriorityEncoder::load(0, 3);
        assert!(!e.has_next());
        assert_eq!(e.next_position(), None);
    }

    #[test]
    fn multi_word_scan_crosses_boundaries() {
        // A 5×5 plane with bits at positions 2, 15, 16, 24.
        let words = [(1u16 << 2) | (1 << 15), (1 << 0) | (1 << 8)];
        let mut e = PriorityEncoder::load_words(&words, 5);
        assert_eq!(e.remaining(), 4);
        assert_eq!(e.next_position(), Some((0, 2)));
        assert_eq!(e.next_position(), Some((3, 0))); // bit 15
        assert_eq!(e.next_position(), Some((3, 1))); // bit 16
        assert_eq!(e.next_position(), Some((4, 4))); // bit 24
        assert_eq!(e.next_position(), None);
    }

    #[test]
    fn prop_matches_bitmask_iteration() {
        // The encoder must visit exactly the positions of the bit-mask
        // representation, in the same order — for one-word and multi-word
        // planes alike.
        run_prop("encoder/matches-bitmask", |g| {
            let (kh, kw) = *g.rng().choose(&[(3usize, 3usize), (5, 5), (7, 7)]);
            let plane = g.sparse_i8(kh * kw, 0.4);
            let bm = BitMaskKernel::from_dense(&plane, kh, kw);
            let mut e = PriorityEncoder::load_words(&bm.map, kw);
            for (r, c, _w) in bm.iter_nz() {
                assert_eq!(e.next_position(), Some((r, c)));
            }
            assert_eq!(e.next_position(), None);
        });
    }
}
