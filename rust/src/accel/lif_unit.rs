//! The hardware LIF module (Fig 7): consumes the PE array's 16-bit partial
//! sums for one (output-channel, time-step) tile, updates the 8-bit
//! membrane potentials, and emits the output spike tile **compressed** —
//! the spike bits are written straight into a word-packed
//! [`SpikePlane`], which is exactly what the Output SRAM stores, with no
//! dense intermediate.
//!
//! Functionally it is the vectorized form of
//! [`crate::model::lif::lif_step_scalar`]; this wrapper adds the tile
//! geometry, the bias preload (the PE array starts from zero and bias is
//! injected here, matching the single write port), and activity counters
//! for the power model.

use crate::model::lif::{lif_step_scalar, LifParams};
use crate::sparse::SpikePlane;

/// LIF module state for one tile × one output channel.
#[derive(Clone, Debug)]
pub struct LifUnit {
    th: usize,
    tw: usize,
    vmem: Vec<i8>,
    fired: Vec<bool>,
    /// Total update events (drives clock/register power).
    pub updates: u64,
    /// Total spikes emitted.
    pub spikes_out: u64,
}

impl LifUnit {
    /// Fresh unit for a `th × tw` tile.
    pub fn new(th: usize, tw: usize) -> Self {
        LifUnit {
            th,
            tw,
            vmem: vec![0; th * tw],
            fired: vec![false; th * tw],
            updates: 0,
            spikes_out: 0,
        }
    }

    /// Advance one time step: `acc` are the PE partial sums, `bias` is the
    /// per-channel bias injected at LIF input. Returns the compressed
    /// spike tile.
    pub fn step(&mut self, p: LifParams, acc: &[i16], bias: i32) -> SpikePlane {
        assert_eq!(acc.len(), self.vmem.len());
        let mut out = SpikePlane::zeros(self.th, self.tw);
        for (i, &a) in acc.iter().enumerate() {
            let (v, s) = lif_step_scalar(self.vmem[i], self.fired[i], a as i32 + bias, p.vth_q);
            self.vmem[i] = v;
            self.fired[i] = s;
            if s {
                out.set(i / self.tw, i % self.tw);
            }
            self.updates += 1;
            self.spikes_out += u64::from(s);
        }
        out
    }

    /// Reset membrane state (new output channel / new frame).
    pub fn reset(&mut self) {
        self.vmem.iter_mut().for_each(|v| *v = 0);
        self.fired.iter_mut().for_each(|f| *f = false);
    }

    /// Re-shape for the next tile, clearing membranes, fire flags and
    /// counters while keeping the allocations — the scratch-arena form of
    /// constructing a fresh unit per tile.
    pub fn reset_for_tile(&mut self, th: usize, tw: usize) {
        self.th = th;
        self.tw = tw;
        self.vmem.clear();
        self.vmem.resize(th * tw, 0);
        self.fired.clear();
        self.fired.resize(th * tw, false);
        self.updates = 0;
        self.spikes_out = 0;
    }

    /// Current membrane potentials (for the output-conv no-reset mode the
    /// controller reads accumulators directly instead).
    pub fn vmem(&self) -> &[i8] {
        &self.vmem
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lif::{LifParams, LifState};
    use crate::util::propcheck::run_prop;

    #[test]
    fn matches_model_lif_state() {
        run_prop("lif-unit/matches-model", |g| {
            let th = g.usize(1, 6);
            let tw = g.usize(1, 6);
            let n = th * tw;
            let p = LifParams { vth_q: g.i64(1, 96) as i32 };
            let bias = g.i64(-20, 20) as i32;
            let mut unit = LifUnit::new(th, tw);
            let mut model = LifState::new(n);
            for _ in 0..3 {
                let acc: Vec<i16> = g.vec(n, |g| g.i64(-200, 200) as i16);
                let tile = unit.step(p, &acc, bias);
                let accb: Vec<i32> = acc.iter().map(|&a| a as i32 + bias).collect();
                let mut want = vec![0u8; n];
                model.step(p, &accb, &mut want);
                assert_eq!(tile.to_dense(), want);
                assert_eq!(unit.vmem(), model.vmem.as_slice());
            }
        });
    }

    #[test]
    fn counters_accumulate() {
        let mut unit = LifUnit::new(2, 2);
        let p = LifParams { vth_q: 10 };
        let tile = unit.step(p, &[20, 0, 20, 0], 0);
        assert_eq!(unit.updates, 4);
        assert_eq!(unit.spikes_out, 2);
        assert_eq!(tile.count_set(), 2);
        assert!(tile.get(0, 0));
        assert!(tile.get(1, 0));
    }

    #[test]
    fn reset_clears() {
        let mut unit = LifUnit::new(1, 2);
        unit.step(LifParams { vth_q: 100 }, &[50, 60], 0);
        assert_ne!(unit.vmem(), &[0, 0]);
        unit.reset();
        assert_eq!(unit.vmem(), &[0, 0]);
    }

    #[test]
    fn reset_for_tile_matches_fresh_unit() {
        let p = LifParams { vth_q: 10 };
        let mut reused = LifUnit::new(3, 3);
        reused.step(p, &[20i16; 9], 0);
        reused.reset_for_tile(2, 2);
        assert_eq!(reused.updates, 0);
        assert_eq!(reused.spikes_out, 0);
        let got = reused.step(p, &[20, 0, 20, 0], 0);
        let mut fresh = LifUnit::new(2, 2);
        let want = fresh.step(p, &[20, 0, 20, 0], 0);
        assert_eq!(got, want);
        assert_eq!(reused.vmem(), fresh.vmem());
        assert_eq!(reused.updates, fresh.updates);
        assert_eq!(reused.spikes_out, fresh.spikes_out);
    }
}
