//! The max-pooling module (Fig 7) — "composed of simple OR gates": 2×2
//! stride-2 OR reduction over binary spike tiles, applied on the fly as
//! spikes leave the LIF module so pooled layers never store the full-rate
//! map.

use crate::tensor::Tensor;

/// OR-gate max-pooling unit with an activity counter.
#[derive(Clone, Debug, Default)]
pub struct MaxPoolUnit {
    /// Number of 2×2 OR reductions performed (4-input OR gates switched).
    pub ops: u64,
}

impl MaxPoolUnit {
    /// Pool one spike tile `(1, h, w)` → `(1, h/2, w/2)`.
    pub fn pool(&mut self, tile: &Tensor<u8>) -> Tensor<u8> {
        let out = crate::ref_impl::maxpool2x2_or(tile);
        self.ops += (out.h * out.w) as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::run_prop;

    #[test]
    fn pools_and_counts() {
        let mut mp = MaxPoolUnit::default();
        let t = Tensor::from_vec(1, 2, 4, vec![0, 1, 0, 0, 0, 0, 0, 1]);
        let out = mp.pool(&t);
        assert_eq!(out.data, vec![1, 1]);
        assert_eq!(mp.ops, 2);
    }

    #[test]
    fn prop_matches_reference() {
        run_prop("maxpool-unit/matches-ref", |g| {
            let h = g.usize(1, 5) * 2;
            let w = g.usize(1, 5) * 2;
            let t = Tensor::from_vec(1, h, w, g.spikes(h * w, 0.4));
            let mut mp = MaxPoolUnit::default();
            assert_eq!(mp.pool(&t), crate::ref_impl::maxpool2x2_or(&t));
        });
    }
}
