//! The max-pooling module (Fig 7) — "composed of simple OR gates": 2×2
//! stride-2 OR reduction over binary spike tiles, applied on the fly as
//! spikes leave the LIF module so pooled layers never store the full-rate
//! map. Operates directly on compressed [`SpikePlane`] tiles: each set
//! input bit ORs into its output cell, O(popcount) per tile.

use crate::sparse::SpikePlane;

/// OR-gate max-pooling unit with an activity counter.
#[derive(Clone, Debug, Default)]
pub struct MaxPoolUnit {
    /// Number of 2×2 OR reductions performed (4-input OR gates switched).
    pub ops: u64,
}

impl MaxPoolUnit {
    /// Pool one compressed spike tile `h × w` → `h/2 × w/2`.
    pub fn pool(&mut self, tile: &SpikePlane) -> SpikePlane {
        let out = tile.maxpool2x2_or();
        self.ops += (out.h * out.w) as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::propcheck::run_prop;

    #[test]
    fn pools_and_counts() {
        let mut mp = MaxPoolUnit::default();
        let t = SpikePlane::from_dense(&[0, 1, 0, 0, 0, 0, 0, 1], 2, 4);
        let out = mp.pool(&t);
        assert_eq!(out.to_dense(), vec![1, 1]);
        assert_eq!(mp.ops, 2);
    }

    #[test]
    fn prop_matches_reference() {
        run_prop("maxpool-unit/matches-ref", |g| {
            let h = g.usize(1, 5) * 2;
            let w = g.usize(1, 5) * 2;
            let data = g.spikes(h * w, 0.4);
            let t = Tensor::from_vec(1, h, w, data.clone());
            let mut mp = MaxPoolUnit::default();
            let got = mp.pool(&SpikePlane::from_dense(&data, h, w));
            assert_eq!(got.to_dense(), crate::ref_impl::maxpool2x2_or(&t).data);
        });
    }
}
