//! TOML-subset parser: `[section]` headers, `key = value` pairs with
//! string / integer / float / boolean values, `#` comments. That is all
//! the project's config files use, and `serde`/`toml` are unavailable
//! offline.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One parsed `[section]`.
#[derive(Clone, Debug, Default)]
pub struct TomlSection {
    values: BTreeMap<String, String>,
}

impl TomlSection {
    /// Raw string value (quotes stripped).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// usize value.
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        // Accept float syntax (e.g. "1e6") for convenience.
        self.get(key)
            .and_then(|v| v.parse::<usize>().ok().or_else(|| v.parse::<f64>().ok().map(|f| f as usize)))
    }

    /// f64 value.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// bool value.
    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// All keys in the section.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

/// A parsed document: named sections plus a root section for keys that
/// appear before any `[section]` header.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    root: TomlSection,
    sections: BTreeMap<String, TomlSection>,
}

impl TomlDoc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut current: Option<String> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let Some(name) = name.strip_suffix(']') else {
                    bail!("line {}: unterminated section header: {raw:?}", lineno + 1);
                };
                let name = name.trim().to_string();
                doc.sections.entry(name.clone()).or_default();
                current = Some(name);
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                let val = unquote(v.trim());
                let section = match &current {
                    Some(name) => doc.sections.get_mut(name).unwrap(),
                    None => &mut doc.root,
                };
                section.values.insert(key, val);
            } else {
                bail!("line {}: expected `key = value` or `[section]`: {raw:?}", lineno + 1);
            }
        }
        Ok(doc)
    }

    /// Parse a document from a file.
    pub fn parse_file(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Named section.
    pub fn section(&self, name: &str) -> Option<&TomlSection> {
        self.sections.get(name)
    }

    /// Keys before any section header.
    pub fn root(&self) -> &TomlSection {
        &self.root
    }
}

fn strip_comment(line: &str) -> &str {
    // Only `#` outside quotes starts a comment; quotes never span lines.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            "top = 1\n[accel]\ntile_h = 18 # comment\nclock_hz = 5e8\nname = \"paper\"\nfast = true\n",
        )
        .unwrap();
        assert_eq!(doc.root().get_usize("top"), Some(1));
        let s = doc.section("accel").unwrap();
        assert_eq!(s.get_usize("tile_h"), Some(18));
        assert_eq!(s.get_f64("clock_hz"), Some(5e8));
        assert_eq!(s.get("name"), Some("paper"));
        assert_eq!(s.get_bool("fast"), Some(true));
    }

    #[test]
    fn hash_in_string_not_comment() {
        let doc = TomlDoc::parse("[a]\nk = \"x # y\"\n").unwrap();
        assert_eq!(doc.section("a").unwrap().get("k"), Some("x # y"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlDoc::parse("not a kv line").is_err());
        assert!(TomlDoc::parse("[unterminated\n").is_err());
    }

    #[test]
    fn missing_section_is_none() {
        let doc = TomlDoc::parse("").unwrap();
        assert!(doc.section("nope").is_none());
    }
}
