//! System-controller configuration registers (§III-D).
//!
//! The paper's accelerator is configured per layer through a register file:
//! convolution parameters (≤512 in/out channels, 1×1–3×3 kernels), data
//! flow parameters (≤4 input/output time steps, ≤1024×576 input), the
//! sparse weight count, max-pooling / encoding-layer indicator bits, and a
//! setup-done indicator. The simulator programs these exactly as a driver
//! would program the chip, and validates ranges like the RTL's assertions.

use anyhow::{bail, Result};

/// Per-layer setup written into the configuration registers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerSetup {
    /// Input channels (1..=512).
    pub in_channels: usize,
    /// Output channels (1..=512).
    pub out_channels: usize,
    /// Kernel height (1..=3).
    pub kh: usize,
    /// Kernel width (1..=3).
    pub kw: usize,
    /// Input time steps (1..=4).
    pub in_t: usize,
    /// Output time steps (1..=4).
    pub out_t: usize,
    /// Input feature height (≤576).
    pub in_h: usize,
    /// Input feature width (≤1024).
    pub in_w: usize,
    /// Number of nonzero (sparse) weights for the layer.
    pub num_sparse_weights: usize,
    /// Max-pool (2×2 OR) fused after this layer.
    pub maxpool: bool,
    /// This is the multibit input-encoding layer (bit-serial, B=8).
    pub encoding: bool,
}

impl LayerSetup {
    /// Input bit planes: 8 for the encoding layer, 1 for spike layers
    /// (the `B` dimension of the KTBC loop).
    pub fn bit_planes(&self) -> usize {
        if self.encoding {
            8
        } else {
            1
        }
    }
}

/// The register file of the system controller.
#[derive(Clone, Debug, Default)]
pub struct ConfigRegisters {
    setup: Option<LayerSetup>,
    /// The §III-D "setup indicator": processing may only start once set.
    setup_done: bool,
}

impl ConfigRegisters {
    /// Program the registers for a layer, enforcing the documented
    /// architectural limits.
    pub fn program(&mut self, s: LayerSetup) -> Result<()> {
        if s.in_channels == 0 || s.in_channels > 512 {
            bail!("in_channels {} out of range 1..=512", s.in_channels);
        }
        if s.out_channels == 0 || s.out_channels > 512 {
            bail!("out_channels {} out of range 1..=512", s.out_channels);
        }
        if !(1..=3).contains(&s.kh) || !(1..=3).contains(&s.kw) {
            bail!("kernel {}x{} out of range 1x1..=3x3", s.kh, s.kw);
        }
        if !(1..=4).contains(&s.in_t) || !(1..=4).contains(&s.out_t) {
            bail!("time steps in={} out={} out of range 1..=4", s.in_t, s.out_t);
        }
        if s.in_h == 0 || s.in_h > 576 || s.in_w == 0 || s.in_w > 1024 {
            bail!("input {}x{} exceeds 1024x576", s.in_w, s.in_h);
        }
        if s.num_sparse_weights > s.out_channels * s.in_channels * s.kh * s.kw {
            bail!("num_sparse_weights exceeds kernel volume");
        }
        self.setup = Some(s);
        self.setup_done = true;
        Ok(())
    }

    /// Whether setup is complete (the §III-D indicator bit).
    pub fn is_ready(&self) -> bool {
        self.setup_done
    }

    /// Read back the programmed setup.
    pub fn setup(&self) -> Option<&LayerSetup> {
        self.setup.as_ref()
    }

    /// Clear between layers.
    pub fn reset(&mut self) {
        self.setup = None;
        self.setup_done = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn valid() -> LayerSetup {
        LayerSetup {
            in_channels: 64,
            out_channels: 128,
            kh: 3,
            kw: 3,
            in_t: 1,
            out_t: 3,
            in_h: 144,
            in_w: 256,
            num_sparse_weights: 1000,
            maxpool: true,
            encoding: false,
        }
    }

    #[test]
    fn program_and_ready() {
        let mut regs = ConfigRegisters::default();
        assert!(!regs.is_ready());
        regs.program(valid()).unwrap();
        assert!(regs.is_ready());
        assert_eq!(regs.setup().unwrap().out_channels, 128);
        regs.reset();
        assert!(!regs.is_ready());
    }

    #[test]
    fn rejects_out_of_range() {
        let mut regs = ConfigRegisters::default();
        assert!(regs.program(LayerSetup { in_channels: 0, ..valid() }).is_err());
        assert!(regs.program(LayerSetup { out_channels: 513, ..valid() }).is_err());
        assert!(regs.program(LayerSetup { kh: 4, ..valid() }).is_err());
        assert!(regs.program(LayerSetup { in_t: 5, ..valid() }).is_err());
        assert!(regs.program(LayerSetup { in_w: 2048, ..valid() }).is_err());
        assert!(regs
            .program(LayerSetup { num_sparse_weights: usize::MAX, ..valid() })
            .is_err());
    }

    #[test]
    fn bit_planes_encoding_vs_spike() {
        assert_eq!(LayerSetup { encoding: true, ..valid() }.bit_planes(), 8);
        assert_eq!(valid().bit_planes(), 1);
    }
}
