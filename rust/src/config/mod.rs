//! Configuration system: a TOML-subset parser (no `serde` offline), typed
//! accelerator/runtime configs, and the hardware configuration registers
//! of §III-D.

pub mod registers;
pub mod toml;

pub use registers::{ConfigRegisters, LayerSetup};
pub use toml::TomlDoc;

use anyhow::{Context, Result};
use std::path::Path;

/// Hardware geometry + technology constants of the implemented chip
/// (Fig 16). All simulator components read from this one struct so a
/// hypothetical design-space sweep can vary it.
#[derive(Clone, Debug, PartialEq)]
pub struct AccelConfig {
    /// PE tile height (paper: 18).
    pub tile_h: usize,
    /// PE tile width (paper: 32).
    pub tile_w: usize,
    /// Number of spatially parallel cores, each a full `tile_h × tile_w`
    /// PE array. The implemented chip is a single core (paper: 1); the
    /// simulator and the analytic model shard each layer's tile grid
    /// round-robin across cores and report the layer makespan (max over
    /// cores) — the §III-A spatial-parallelism scaling axis.
    pub num_cores: usize,
    /// Clock frequency in Hz (paper: 500 MHz).
    pub clock_hz: f64,
    /// Weight precision in bits (paper: 8).
    pub weight_bits: usize,
    /// Membrane-potential storage bits (paper: 8).
    pub vmem_bits: usize,
    /// Accumulator bits (paper: 16).
    pub acc_bits: usize,
    /// NZ Weight SRAM capacity in bytes. Sizing rule from §IV-D: large
    /// enough for the largest layer's compressed weights (the paper's
    /// network needed 216 KB total; our reproduction's b4.stack1 is a bit
    /// wider, needing 192 KB NZ + 128 KB map — see DESIGN.md §8).
    pub nz_weight_sram_bytes: usize,
    /// Weight Map SRAM capacity in bytes.
    pub weight_map_sram_bytes: usize,
    /// Input SRAM capacity in bytes (paper evaluates 36 KB and 81 KB).
    pub input_sram_bytes: usize,
    /// Output SRAM capacity in bytes.
    pub output_sram_bytes: usize,
    /// Number of input/output SRAM banks (paper: 4 each).
    pub io_banks: usize,
    /// DRAM energy per bit in picojoules (paper: 70 pJ/bit DDR3).
    pub dram_pj_per_bit: f64,
    /// Max supported input channels (§III-D: 512).
    pub max_in_channels: usize,
    /// Max supported output channels (§III-D: 512).
    pub max_out_channels: usize,
    /// Max supported time steps (§III-D: 4).
    pub max_time_steps: usize,
    /// Supply voltage (paper: 0.9 V) — used by normalized-efficiency math.
    pub voltage: f64,
    /// Process node in nm (paper: 28).
    pub process_nm: f64,
    /// Which PE datapath executes the gated one-to-all product (bit-mask
    /// baseline, the Prosperity-style product-sparsity path that mines
    /// partial-sum reuse across tile rows, or the temporal-delta path
    /// that additionally replays cached accumulator deltas across time
    /// steps). Bit-exact every way; only the cycle accounting differs.
    pub datapath: Datapath,
    /// Capacity (in planes) of the temporal-delta datapath's cross-tile
    /// pattern cache: mined [`crate::accel::ReuseForest`]s are kept in a
    /// small LRU keyed by row-bitmap hash so identical row patterns in
    /// neighboring tiles/channels skip re-mining. Ignored by the other
    /// datapaths.
    pub temporal_cache_planes: usize,
}

impl AccelConfig {
    /// The paper's implemented configuration (Fig 16) with the 36 KB
    /// input SRAM of §IV-D.
    pub fn paper() -> Self {
        AccelConfig {
            tile_h: 18,
            tile_w: 32,
            num_cores: 1,
            clock_hz: 500e6,
            weight_bits: 8,
            vmem_bits: 8,
            acc_bits: 16,
            nz_weight_sram_bytes: 192 * 1024,
            weight_map_sram_bytes: 128 * 1024,
            input_sram_bytes: 36 * 1024,
            output_sram_bytes: 36 * 1024,
            io_banks: 4,
            dram_pj_per_bit: 70.0,
            max_in_channels: 512,
            max_out_channels: 512,
            max_time_steps: 4,
            voltage: 0.9,
            process_nm: 28.0,
            datapath: Datapath::BitMask,
            temporal_cache_planes: 64,
        }
    }

    /// §IV-D variant: input SRAM enlarged to 81 KB so a 32×18 tile with
    /// 384 channels × 3 time steps stays on chip.
    pub fn paper_large_input_sram() -> Self {
        AccelConfig { input_sram_bytes: 81 * 1024, ..Self::paper() }
    }

    /// `num_cores` variant (design-space sweeps, `--cores N`).
    pub fn with_cores(mut self, cores: usize) -> Self {
        self.num_cores = cores.max(1);
        self
    }

    /// `datapath` variant (design-space sweeps, `--datapath D`).
    pub fn with_datapath(mut self, datapath: Datapath) -> Self {
        self.datapath = datapath;
        self
    }

    /// `temporal_cache_planes` variant (cache-size sweeps).
    pub fn with_temporal_cache(mut self, planes: usize) -> Self {
        self.temporal_cache_planes = planes;
        self
    }

    /// Number of PEs per core (one per output pixel of the tile;
    /// paper: 576).
    pub fn num_pes(&self) -> usize {
        self.tile_h * self.tile_w
    }

    /// Total PEs across all cores.
    pub fn total_pes(&self) -> usize {
        self.num_pes() * self.num_cores.max(1)
    }

    /// Load overrides from a TOML-subset file section `[accel]`.
    pub fn from_file(path: &Path) -> Result<Self> {
        let doc = TomlDoc::parse_file(path)
            .with_context(|| format!("loading accel config {}", path.display()))?;
        Ok(Self::from_doc(&doc))
    }

    /// Apply `[accel]` overrides from a parsed document.
    pub fn from_doc(doc: &TomlDoc) -> Self {
        let mut cfg = Self::paper();
        if let Some(s) = doc.section("accel") {
            cfg.tile_h = s.get_usize("tile_h").unwrap_or(cfg.tile_h);
            cfg.tile_w = s.get_usize("tile_w").unwrap_or(cfg.tile_w);
            cfg.num_cores = s.get_usize("num_cores").unwrap_or(cfg.num_cores).max(1);
            cfg.clock_hz = s.get_f64("clock_hz").unwrap_or(cfg.clock_hz);
            cfg.weight_bits = s.get_usize("weight_bits").unwrap_or(cfg.weight_bits);
            cfg.input_sram_bytes = s.get_usize("input_sram_bytes").unwrap_or(cfg.input_sram_bytes);
            cfg.output_sram_bytes =
                s.get_usize("output_sram_bytes").unwrap_or(cfg.output_sram_bytes);
            cfg.nz_weight_sram_bytes =
                s.get_usize("nz_weight_sram_bytes").unwrap_or(cfg.nz_weight_sram_bytes);
            cfg.weight_map_sram_bytes =
                s.get_usize("weight_map_sram_bytes").unwrap_or(cfg.weight_map_sram_bytes);
            cfg.dram_pj_per_bit = s.get_f64("dram_pj_per_bit").unwrap_or(cfg.dram_pj_per_bit);
            if let Some(d) = s.get("datapath") {
                cfg.datapath = Datapath::parse(d).unwrap_or(cfg.datapath);
            }
            cfg.temporal_cache_planes =
                s.get_usize("temporal_cache_planes").unwrap_or(cfg.temporal_cache_planes);
        }
        cfg
    }
}

/// Which PE datapath the simulator's gated one-to-all product runs. All
/// are bit-exact against the golden model; they differ in how work is
/// counted (and, at high pattern overlap or temporal correlation, how
/// much of it exists).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Datapath {
    /// The paper's baseline: every enabled (pixel, weight) pair costs one
    /// MAC, silent pixels are gated.
    BitMask,
    /// Prosperity-style product sparsity: a per-tile reuse forest over the
    /// word-packed spike rows detects equal/subset row patterns, computes
    /// each unique pattern once and replays deltas for subsumed rows —
    /// fewer MACs at high overlap, at a per-plane mining cost.
    Prosperity,
    /// Temporal-delta reuse on top of the product-sparsity path:
    /// consecutive time steps of a tile plane are row-wise XOR-diffed,
    /// unchanged output rows replay the previous step's cached
    /// accumulator delta instead of re-walking the forest (full compute
    /// only at `t = 0`), and mined forests are shared across
    /// tiles/channels through a small LRU pattern cache.
    TemporalDelta,
}

impl Datapath {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Datapath> {
        match s {
            "bitmask" | "bit-mask" => Some(Datapath::BitMask),
            "prosperity" | "product" => Some(Datapath::Prosperity),
            "temporal-delta" | "temporal" => Some(Datapath::TemporalDelta),
            _ => None,
        }
    }

    /// Canonical spelling.
    pub fn label(self) -> &'static str {
        match self {
            Datapath::BitMask => "bitmask",
            Datapath::Prosperity => "prosperity",
            Datapath::TemporalDelta => "temporal-delta",
        }
    }

    /// Every datapath, in CLI order.
    pub fn all() -> [Datapath; 3] {
        [Datapath::BitMask, Datapath::Prosperity, Datapath::TemporalDelta]
    }
}

/// How a [`ClusterConfig`]'s chips split one frame's work (the cluster
/// subsystem's sharding axis; see `crate::cluster`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Whole frames dealt round-robin across chips: zero inter-chip
    /// traffic, per-frame latency unchanged, throughput scales with chips.
    FrameParallel,
    /// Layers partitioned into contiguous pipeline stages, one stage per
    /// chip; compressed spike planes ship between stages.
    LayerPipeline,
    /// Every layer's tile grid split across all chips' cores, with halo
    /// exchange between neighboring tiles on different chips.
    TileSplit,
}

impl ShardPolicy {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<ShardPolicy> {
        match s {
            "frame" | "frame-parallel" => Some(ShardPolicy::FrameParallel),
            "pipeline" | "layer-pipeline" => Some(ShardPolicy::LayerPipeline),
            "tile" | "tile-split" => Some(ShardPolicy::TileSplit),
            _ => None,
        }
    }

    /// Canonical spelling.
    pub fn label(self) -> &'static str {
        match self {
            ShardPolicy::FrameParallel => "frame",
            ShardPolicy::LayerPipeline => "pipeline",
            ShardPolicy::TileSplit => "tile",
        }
    }

    /// Every policy, in CLI order.
    pub fn all() -> [ShardPolicy; 3] {
        [ShardPolicy::FrameParallel, ShardPolicy::LayerPipeline, ShardPolicy::TileSplit]
    }
}

/// Multi-chip cluster configuration: N identical chips (each an
/// [`AccelConfig`]) joined by a DRAM-class interconnect. The link numbers
/// feed `crate::accel::dram::LinkSpec`; they live here so the whole
/// cluster geometry loads from one `[cluster]` TOML section.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Simulated chips (1 = the plain single-chip design).
    pub num_chips: usize,
    /// How a frame's work is sharded across chips.
    pub policy: ShardPolicy,
    /// Inter-chip link bandwidth in bits per core-clock cycle (a 64-bit
    /// DDR-style link at the core clock ⇒ 128 bits/cycle).
    pub link_bits_per_cycle: u64,
    /// Fixed per-transfer link latency in core-clock cycles.
    pub link_latency_cycles: u64,
    /// Link energy per bit in picojoules (off-chip SerDes + DRAM-class
    /// wires; cheaper than the 70 pJ/bit DDR3 hop but far above on-chip).
    pub link_pj_per_bit: f64,
    /// Per-chip hardware geometry.
    pub chip: AccelConfig,
}

impl ClusterConfig {
    /// One paper chip, no interconnect in play.
    pub fn single_chip() -> Self {
        ClusterConfig {
            num_chips: 1,
            policy: ShardPolicy::FrameParallel,
            link_bits_per_cycle: 128,
            link_latency_cycles: 200,
            link_pj_per_bit: 10.0,
            chip: AccelConfig::paper(),
        }
    }

    /// `num_chips` variant (sweeps, `--chips N`).
    pub fn with_chips(mut self, chips: usize) -> Self {
        self.num_chips = chips.max(1);
        self
    }

    /// `policy` variant (sweeps, `--shard-policy P`).
    pub fn with_policy(mut self, policy: ShardPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Load from a TOML-subset file: `[accel]` configures the per-chip
    /// geometry, `[cluster]` the chip count, policy and link.
    pub fn from_file(path: &Path) -> Result<Self> {
        let doc = TomlDoc::parse_file(path)
            .with_context(|| format!("loading cluster config {}", path.display()))?;
        let mut cfg = Self::single_chip();
        cfg.chip = AccelConfig::from_doc(&doc);
        if let Some(s) = doc.section("cluster") {
            cfg.num_chips = s.get_usize("num_chips").unwrap_or(cfg.num_chips).max(1);
            if let Some(p) = s.get("policy") {
                cfg.policy = ShardPolicy::parse(p).ok_or_else(|| {
                    anyhow::anyhow!("unknown shard policy {p:?} in {}", path.display())
                })?;
            }
            cfg.link_bits_per_cycle = s
                .get_usize("link_bits_per_cycle")
                .map(|v| v as u64)
                .unwrap_or(cfg.link_bits_per_cycle)
                .max(1);
            cfg.link_latency_cycles = s
                .get_usize("link_latency_cycles")
                .map(|v| v as u64)
                .unwrap_or(cfg.link_latency_cycles);
            cfg.link_pj_per_bit = s.get_f64("link_pj_per_bit").unwrap_or(cfg.link_pj_per_bit);
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_matches_fig16() {
        let c = AccelConfig::paper();
        assert_eq!(c.num_pes(), 576);
        // The implemented chip is a single core.
        assert_eq!(c.num_cores, 1);
        assert_eq!(c.total_pes(), 576);
        assert_eq!(c.with_cores(4).total_pes(), 4 * 576);
        assert_eq!(AccelConfig::paper().with_cores(0).num_cores, 1);
        assert_eq!(c.clock_hz, 500e6);
        assert_eq!(c.weight_bits, 8);
        assert_eq!(c.acc_bits, 16);
        assert_eq!(c.io_banks, 4);
    }

    #[test]
    fn large_sram_variant() {
        let c = AccelConfig::paper_large_input_sram();
        assert_eq!(c.input_sram_bytes, 81 * 1024);
        assert_eq!(c.tile_h, AccelConfig::paper().tile_h);
    }

    #[test]
    fn from_file_overrides() {
        let dir = std::env::temp_dir().join("scsnn_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("accel.toml");
        std::fs::write(&p, "[accel]\ntile_h = 9\nclock_hz = 1e9\n").unwrap();
        let c = AccelConfig::from_file(&p).unwrap();
        assert_eq!(c.tile_h, 9);
        assert_eq!(c.clock_hz, 1e9);
        assert_eq!(c.tile_w, 32); // untouched default
    }

    #[test]
    fn shard_policy_spellings() {
        assert_eq!(ShardPolicy::parse("frame"), Some(ShardPolicy::FrameParallel));
        assert_eq!(ShardPolicy::parse("layer-pipeline"), Some(ShardPolicy::LayerPipeline));
        assert_eq!(ShardPolicy::parse("tile"), Some(ShardPolicy::TileSplit));
        assert_eq!(ShardPolicy::parse("bogus"), None);
        for p in ShardPolicy::all() {
            assert_eq!(ShardPolicy::parse(p.label()), Some(p), "{p:?} round-trips");
        }
    }

    #[test]
    fn datapath_spellings_round_trip() {
        assert_eq!(Datapath::parse("bitmask"), Some(Datapath::BitMask));
        assert_eq!(Datapath::parse("prosperity"), Some(Datapath::Prosperity));
        assert_eq!(Datapath::parse("temporal-delta"), Some(Datapath::TemporalDelta));
        assert_eq!(Datapath::parse("temporal"), Some(Datapath::TemporalDelta));
        assert_eq!(Datapath::parse("bogus"), None);
        for d in Datapath::all() {
            assert_eq!(Datapath::parse(d.label()), Some(d), "{d:?} round-trips");
        }
        assert_eq!(AccelConfig::paper().datapath, Datapath::BitMask);
        assert_eq!(
            AccelConfig::paper().with_datapath(Datapath::Prosperity).datapath,
            Datapath::Prosperity
        );
    }

    #[test]
    fn datapath_from_toml() {
        let dir = std::env::temp_dir().join("scsnn_datapath_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("accel.toml");
        std::fs::write(&p, "[accel]\ndatapath = \"prosperity\"\n").unwrap();
        let c = AccelConfig::from_file(&p).unwrap();
        assert_eq!(c.datapath, Datapath::Prosperity);
        std::fs::write(
            &p,
            "[accel]\ndatapath = \"temporal-delta\"\ntemporal_cache_planes = 16\n",
        )
        .unwrap();
        let c = AccelConfig::from_file(&p).unwrap();
        assert_eq!(c.datapath, Datapath::TemporalDelta);
        assert_eq!(c.temporal_cache_planes, 16);
        assert_eq!(AccelConfig::paper().temporal_cache_planes, 64);
        assert_eq!(AccelConfig::paper().with_temporal_cache(8).temporal_cache_planes, 8);
    }

    #[test]
    fn cluster_defaults_are_single_chip() {
        let c = ClusterConfig::single_chip();
        assert_eq!(c.num_chips, 1);
        assert_eq!(c.policy, ShardPolicy::FrameParallel);
        assert_eq!(c.chip, AccelConfig::paper());
        assert_eq!(c.with_chips(0).num_chips, 1);
        assert_eq!(
            ClusterConfig::single_chip().with_chips(4).with_policy(ShardPolicy::TileSplit).policy,
            ShardPolicy::TileSplit
        );
    }

    #[test]
    fn cluster_from_file_reads_both_sections() {
        let dir = std::env::temp_dir().join("scsnn_cluster_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cluster.toml");
        std::fs::write(
            &p,
            "[accel]\nnum_cores = 2\n\n[cluster]\nnum_chips = 4\npolicy = \"pipeline\"\nlink_bits_per_cycle = 64\nlink_pj_per_bit = 5.0\n",
        )
        .unwrap();
        let c = ClusterConfig::from_file(&p).unwrap();
        assert_eq!(c.num_chips, 4);
        assert_eq!(c.policy, ShardPolicy::LayerPipeline);
        assert_eq!(c.link_bits_per_cycle, 64);
        assert_eq!(c.link_pj_per_bit, 5.0);
        assert_eq!(c.chip.num_cores, 2);
        assert_eq!(c.link_latency_cycles, 200); // untouched default
    }
}
