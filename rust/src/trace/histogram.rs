//! Fixed log-bucket latency histograms (HDR-style): p50/p95/p99
//! without storing every sample, mergeable across threads.
//!
//! Values are recorded in microseconds into buckets with 16
//! sub-buckets per octave (`SUB_BITS = 4`); quantiles report the
//! bucket *midpoint*, so any reported quantile is within ~3.125%
//! (half a sub-bucket) relative error of the true sample — plenty
//! for tail-latency reporting — while the whole histogram is a fixed
//! 976-slot array covering 1 µs .. ~584000 years.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;

/// Sub-bucket resolution: 2^SUB_BITS buckets per power of two.
const SUB_BITS: u32 = 4;
const SUBS: usize = 1 << SUB_BITS;

/// Bucket count for the full u64 range: the first 16 values map 1:1,
/// then 16 sub-buckets for each exponent 4..=63.
const NBUCKETS: usize = SUBS + (64 - SUB_BITS as usize) * SUBS; // 976

fn bucket_of(v: u64) -> usize {
    if v < SUBS as u64 {
        return v as usize;
    }
    let e = 63 - v.leading_zeros(); // e >= SUB_BITS
    (((e - (SUB_BITS - 1)) as usize) << SUB_BITS) + ((v >> (e - SUB_BITS)) & (SUBS as u64 - 1)) as usize
}

/// Lowest value mapping into bucket `idx` (inverse of [`bucket_of`]).
fn bucket_low(idx: usize) -> u64 {
    if idx < SUBS {
        return idx as u64;
    }
    let e = (idx >> SUB_BITS) as u32 + (SUB_BITS - 1);
    (1u64 << e) + (((idx & (SUBS - 1)) as u64) << (e - SUB_BITS))
}

/// A mergeable log-bucket latency histogram. `Default` is empty;
/// bucket storage is allocated lazily on the first observation.
#[derive(Clone, Debug, Default)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    min_us: u64,
    max_us: u64,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency sample (floored to whole microseconds).
    pub fn observe(&mut self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        if self.buckets.is_empty() {
            self.buckets = vec![0; NBUCKETS];
            self.min_us = us;
            self.max_us = us;
        } else {
            self.min_us = self.min_us.min(us);
            self.max_us = self.max_us.max(us);
        }
        self.buckets[bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        if other.count == 0 {
            return;
        }
        if self.buckets.is_empty() {
            *self = other.clone();
            return;
        }
        for (b, &c) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += c;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn min(&self) -> Duration {
        Duration::from_micros(if self.count == 0 { 0 } else { self.min_us })
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(if self.count == 0 { 0 } else { self.max_us })
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        // Divide in f64 and round: integer division truncates, biasing
        // reported means low (e.g. {10, 20, 20}µs → 16µs instead of 17µs).
        Duration::from_micros((self.sum_us as f64 / self.count as f64).round() as u64)
    }

    /// Nearest-rank quantile (`q` in [0, 1]): the *midpoint* of the
    /// bucket holding the rank-`ceil(q·count)` sample, clamped into
    /// the observed [min, max] range. The bucket lower bound would
    /// under-report by up to a full sub-bucket (≤6.25%); the midpoint
    /// halves the worst case to ≤3.125%. The extreme ranks are known
    /// exactly — rank 1 is the observed min and rank `count` the
    /// observed max — so q=0 and q=1 are exact.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        if rank == 1 {
            return Duration::from_micros(self.min_us);
        }
        if rank == self.count {
            return Duration::from_micros(self.max_us);
        }
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let low = bucket_low(idx);
                let high = if idx + 1 < NBUCKETS { bucket_low(idx + 1) } else { u64::MAX };
                let mid = low + (high - low) / 2;
                return Duration::from_micros(mid.clamp(self.min_us, self.max_us));
            }
        }
        Duration::from_micros(self.max_us)
    }

    /// Summary object: `{count, mean_ms, min_ms, max_ms, p50_ms,
    /// p95_ms, p99_ms}`.
    pub fn to_json(&self) -> Json {
        let ms = |d: Duration| Json::Num(d.as_secs_f64() * 1e3);
        let mut o = BTreeMap::new();
        o.insert("count".into(), Json::Num(self.count as f64));
        o.insert("mean_ms".into(), ms(self.mean()));
        o.insert("min_ms".into(), ms(self.min()));
        o.insert("max_ms".into(), ms(self.max()));
        o.insert("p50_ms".into(), ms(self.quantile(0.50)));
        o.insert("p95_ms".into(), ms(self.quantile(0.95)));
        o.insert("p99_ms".into(), ms(self.quantile(0.99)));
        Json::Obj(o)
    }
}

/// Named histograms behind one mutex: threads observe through a shared
/// handle, readers snapshot by name. The registry lock is held only
/// for the O(log-buckets) observe, so contention stays negligible next
/// to frame work.
#[derive(Debug, Default)]
pub struct HistogramRegistry {
    inner: Mutex<BTreeMap<String, LatencyHistogram>>,
}

impl HistogramRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&self, name: &str, d: Duration) {
        // Look up by `&str` first: `entry()` would allocate a fresh
        // String per observation under the lock; the steady state is
        // always a hit on an existing slot.
        let mut inner = self.inner.lock().unwrap();
        match inner.get_mut(name) {
            Some(h) => h.observe(d),
            None => inner.entry(name.to_string()).or_default().observe(d),
        }
    }

    /// Merge a locally accumulated histogram (e.g. one per worker
    /// thread) into the named slot.
    pub fn merge_from(&self, name: &str, h: &LatencyHistogram) {
        let mut inner = self.inner.lock().unwrap();
        match inner.get_mut(name) {
            Some(slot) => slot.merge(h),
            None => inner.entry(name.to_string()).or_default().merge(h),
        }
    }

    pub fn get(&self, name: &str) -> Option<LatencyHistogram> {
        self.inner.lock().unwrap().get(name).cloned()
    }

    pub fn snapshot(&self) -> BTreeMap<String, LatencyHistogram> {
        self.inner.lock().unwrap().clone()
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.snapshot().into_iter().map(|(name, h)| (name, h.to_json())).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_mapping_round_trips() {
        for v in [0u64, 1, 15, 16, 17, 31, 32, 63, 64, 1000, 1 << 20, u64::MAX] {
            let idx = bucket_of(v);
            assert!(idx < NBUCKETS, "bucket {idx} out of range for {v}");
            let low = bucket_low(idx);
            assert!(low <= v, "bucket_low({idx})={low} > {v}");
            if idx + 1 < NBUCKETS {
                assert!(bucket_low(idx + 1) > v, "value {v} not below next bucket");
            }
            // Relative error bound: bucket width / low <= 1/16.
            if v >= 16 {
                assert!((v - low) as f64 / v as f64 <= 1.0 / 16.0);
            }
        }
        assert_eq!(bucket_of(u64::MAX), NBUCKETS - 1);
    }

    #[test]
    fn quantiles_are_nearest_rank_within_bucket_error() {
        let mut h = LatencyHistogram::new();
        for ms in [10u64, 20, 30, 40] {
            h.observe(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), Duration::from_millis(10));
        assert_eq!(h.max(), Duration::from_millis(40));
        let p0 = h.quantile(0.0).as_secs_f64();
        assert!((p0 - 0.010).abs() < 0.010 / 16.0);
        let p99 = h.quantile(0.99).as_secs_f64();
        assert!(p99 >= 0.030, "p99 {p99} should reach the last sample's bucket");
        assert!(h.quantile(1.0) <= Duration::from_millis(40));
        // Monotone in q.
        let qs: Vec<Duration> = (0..=10).map(|i| h.quantile(i as f64 / 10.0)).collect();
        for pair in qs.windows(2) {
            assert!(pair[1] >= pair[0]);
        }
    }

    #[test]
    fn mean_rounds_instead_of_truncating() {
        // {10, 20, 20}µs → 50/3 = 16.67µs; integer division would
        // truncate to 16µs, the rounded mean is 17µs.
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 20] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.mean(), Duration::from_micros(17));
        // An integral mean stays exact.
        let mut e = LatencyHistogram::new();
        for us in [10u64, 20, 30] {
            e.observe(Duration::from_micros(us));
        }
        assert_eq!(e.mean(), Duration::from_micros(20));
    }

    #[test]
    fn quantile_reports_bucket_midpoint_not_lower_bound() {
        // 960µs is exactly a bucket lower bound ([960, 992)); as an
        // interior rank (rank 2 of 3) neither the [min, max] clamp nor
        // the exact-extreme rule masks the midpoint, so the quantile
        // must be 976µs, not the lower bound 960µs.
        let mut h = LatencyHistogram::new();
        h.observe(Duration::from_micros(900));
        h.observe(Duration::from_micros(960));
        h.observe(Duration::from_micros(2000));
        assert_eq!(h.quantile(0.5), Duration::from_micros(976));
        // Midpoint relative error is within half a sub-bucket (3.125%).
        let true_v = 1000.0e-6;
        let mut g = LatencyHistogram::new();
        g.observe(Duration::from_micros(500));
        g.observe(Duration::from_micros(1000));
        g.observe(Duration::from_micros(4000));
        let p50 = g.quantile(0.5).as_secs_f64();
        assert!((p50 - true_v).abs() / true_v <= 1.0 / 32.0, "p50 {p50} vs {true_v}");
    }

    #[test]
    fn quantile_edges_q0_q1_and_single_sample() {
        // Single sample: every quantile is exactly that sample.
        let mut one = LatencyHistogram::new();
        one.observe(Duration::from_micros(12345));
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(one.quantile(q), Duration::from_micros(12345));
        }
        // q=0 is exactly the observed min, q=1 exactly the observed max.
        let mut h = LatencyHistogram::new();
        for us in [100u64, 5000, 90000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.quantile(0.0), Duration::from_micros(100));
        assert_eq!(h.quantile(1.0), Duration::from_micros(90000));
        assert!(h.quantile(1.0) >= h.quantile(0.99));
    }

    #[test]
    fn merge_matches_combined_observation() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for i in 0..100u64 {
            let d = Duration::from_micros(17 * i + 3);
            if i % 2 == 0 { a.observe(d) } else { b.observe(d) }
            all.observe(d);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(a.quantile(q), all.quantile(q));
        }
    }

    #[test]
    fn registry_observes_and_merges_across_threads() {
        let reg = HistogramRegistry::new();
        std::thread::scope(|scope| {
            for w in 0..4 {
                let reg = &reg;
                scope.spawn(move || {
                    for i in 0..50 {
                        reg.observe("queue", Duration::from_micros(100 * w + i));
                    }
                });
            }
        });
        let h = reg.get("queue").expect("histogram recorded");
        assert_eq!(h.count(), 200);
        assert!(reg.get("missing").is_none());
        let json = reg.to_json().to_string_compact();
        assert!(json.contains("queue"));
        assert!(json.contains("p99_ms"));
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
        let mut other = LatencyHistogram::new();
        other.merge(&h);
        assert!(other.is_empty());
    }
}
