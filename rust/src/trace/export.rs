//! Trace exporters: Chrome `trace_event` JSON (loadable in
//! `chrome://tracing` / Perfetto) and a line-per-event JSONL stream.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::{TraceEvent, TraceKind};

fn chip_json(c: Option<usize>) -> Json {
    match c {
        Some(i) => Json::Num(i as f64),
        None => Json::Str("host".into()),
    }
}

/// Structured `args` payload for one event (shared by both exporters).
fn args_json(kind: &TraceKind) -> Json {
    let mut o = BTreeMap::new();
    match *kind {
        TraceKind::RequestQueued { request }
        | TraceKind::RequestService { request }
        | TraceKind::RequestShed { request }
        | TraceKind::RequestDeadlineMissed { request } => {
            o.insert("request".into(), Json::Num(request as f64));
        }
        TraceKind::EngineJob { frame } => {
            o.insert("frame".into(), Json::Num(frame as f64));
        }
        TraceKind::StageJob { frame, stage, unit } | TraceKind::LeaseWait { frame, stage, unit } => {
            o.insert("frame".into(), Json::Num(frame as f64));
            o.insert("stage".into(), Json::Num(stage as f64));
            o.insert("unit".into(), Json::Num(unit as f64));
        }
        TraceKind::Layer { frame, layer, unit } => {
            o.insert("frame".into(), Json::Num(frame as f64));
            o.insert("layer".into(), Json::Num(layer as f64));
            o.insert("unit".into(), Json::Num(unit as f64));
        }
        TraceKind::Transfer { frame, index, src, dst, bits, cycles } => {
            o.insert("frame".into(), Json::Num(frame as f64));
            o.insert("index".into(), Json::Num(index as f64));
            o.insert("src".into(), chip_json(src));
            o.insert("dst".into(), chip_json(dst));
            o.insert("bits".into(), Json::Num(bits as f64));
            o.insert("cycles".into(), Json::Num(cycles as f64));
        }
    }
    Json::Obj(o)
}

/// One Chrome `trace_event` object: complete spans (`ph:"X"` with
/// `ts`/`dur` in microseconds) and thread-scoped instants (`ph:"i"`).
fn chrome_event(ev: &TraceEvent) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".into(), Json::Str(ev.kind.name().into()));
    o.insert("cat".into(), Json::Str(ev.kind.category().into()));
    o.insert("pid".into(), Json::Num(0.0));
    o.insert("tid".into(), Json::Num(ev.track as f64));
    o.insert("ts".into(), Json::Num(ev.start.as_secs_f64() * 1e6));
    if ev.dur.is_zero() {
        o.insert("ph".into(), Json::Str("i".into()));
        o.insert("s".into(), Json::Str("t".into()));
    } else {
        o.insert("ph".into(), Json::Str("X".into()));
        o.insert("dur".into(), Json::Num(ev.dur.as_secs_f64() * 1e6));
    }
    o.insert("args".into(), args_json(&ev.kind));
    Json::Obj(o)
}

/// The full Chrome trace document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let mut o = BTreeMap::new();
    o.insert("traceEvents".into(), Json::Arr(events.iter().map(chrome_event).collect()));
    o.insert("displayTimeUnit".into(), Json::Str("ms".into()));
    Json::Obj(o)
}

/// JSONL stream: one compact Chrome-format event object per line
/// (grep/`jq`-friendly; trailing newline when non-empty).
pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&chrome_event(ev).to_string_compact());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceSink;
    use std::time::Duration;

    fn sample_events() -> Vec<TraceEvent> {
        let sink = TraceSink::enabled();
        let t = sink.now();
        sink.span(TraceKind::StageJob { frame: 0, stage: 0, unit: 1 }, t);
        sink.span_at(
            TraceKind::RequestService { request: 2 },
            Duration::from_micros(10),
            Duration::from_micros(35),
        );
        sink.instant(TraceKind::Transfer {
            frame: 0,
            index: 0,
            src: None,
            dst: Some(1),
            bits: 128,
            cycles: 4,
        });
        sink.events()
    }

    #[test]
    fn chrome_trace_round_trips_through_parser() {
        let events = sample_events();
        let doc = chrome_trace_json(&events);
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).expect("chrome trace parses");
        let list = match parsed.get("traceEvents") {
            Some(Json::Arr(a)) => a.clone(),
            other => panic!("traceEvents missing: {other:?}"),
        };
        assert_eq!(list.len(), events.len());
        // Spans are ph:"X" with dur; instants are ph:"i" with scope.
        let phases: Vec<String> = list
            .iter()
            .map(|e| match e.get("ph") {
                Some(Json::Str(s)) => s.clone(),
                other => panic!("ph missing: {other:?}"),
            })
            .collect();
        assert!(phases.contains(&"X".to_string()));
        assert!(phases.contains(&"i".to_string()));
        for e in &list {
            assert!(e.get("name").is_some());
            assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
            assert!(e.get("args").is_some());
        }
    }

    #[test]
    fn span_at_preserves_microsecond_timestamps() {
        let events = sample_events();
        let doc = chrome_trace_json(&events);
        let list = match doc.get("traceEvents") {
            Some(Json::Arr(a)) => a.clone(),
            _ => unreachable!(),
        };
        let svc = list
            .iter()
            .find(|e| matches!(e.get("name"), Some(Json::Str(s)) if s == "request.service"))
            .expect("service span exported");
        assert_eq!(svc.get("ts").and_then(|t| t.as_f64()), Some(10.0));
        assert_eq!(svc.get("dur").and_then(|t| t.as_f64()), Some(25.0));
    }

    #[test]
    fn jsonl_has_one_parseable_object_per_line() {
        let events = sample_events();
        let jsonl = to_jsonl(&events);
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), events.len());
        for line in lines {
            let obj = Json::parse(line).expect("jsonl line parses");
            assert!(obj.get("name").is_some());
        }
        assert!(to_jsonl(&[]).is_empty());
    }
}
