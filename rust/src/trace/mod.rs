//! Unified tracing & telemetry: typed spans/instants recorded into a
//! lock-cheap, bounded, shareable sink, plus log-bucket latency
//! histograms ([`histogram`]) and Chrome `trace_event` / JSONL export
//! ([`export`]).
//!
//! Design constraints (see README §Observability):
//!
//! - **Zero-cost when disabled.** A [`TraceSink`] is either enabled
//!   (backed by a shared buffer) or a no-op behind the same API;
//!   `TraceSink::disabled()` never allocates, never takes a lock, and
//!   `now()` returns `None` so callers skip even the clock read.
//! - **Lock-cheap when enabled.** Events land in one of a fixed set of
//!   sharded buffers keyed by the recording thread, so concurrent
//!   workers rarely contend on the same mutex; each push is a single
//!   short critical section.
//! - **Bounded memory.** The sink holds at most `cap` events; overflow
//!   increments a drop counter instead of growing without bound.
//! - **Deterministic ordering/counts.** Wall-clock timestamps are
//!   nondeterministic by nature, so determinism is defined over the
//!   *logical* identity of events: [`TraceSink::events`] returns the
//!   merged buffers sorted by [`TraceKind::sort_key`] (kind tag + frame
//!   + stage/layer/unit coordinates), which depends only on what work
//!   ran — not when or on which thread. The same seed and config
//!   therefore yield byte-identical event sequences for any worker
//!   count (`tests/trace_determinism.rs`).

pub mod export;
pub mod histogram;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Number of independently locked event buffers. Threads hash onto
/// shards by their track id, so contention only occurs when more than
/// `SHARDS` threads trace simultaneously.
const SHARDS: usize = 16;

/// Default bound on retained events (~14 MB at 56 B/event).
const DEFAULT_CAP: usize = 1 << 18;

/// A typed trace event identity: what happened, with enough
/// coordinates to order it deterministically. Times live on
/// [`TraceEvent`], not here.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// An open-loop request waiting between arrival and service start.
    RequestQueued { request: usize },
    /// An open-loop request being serviced (service start → done).
    RequestService { request: usize },
    /// An open-loop request shed by the SLO admission policy
    /// (instant at the shed decision; the request never ran).
    RequestShed { request: usize },
    /// An open-loop request dropped because its deadline passed before
    /// service began (instant; no chip cycles were spent on it).
    RequestDeadlineMissed { request: usize },
    /// A whole-frame job on a streaming-engine worker thread.
    EngineJob { frame: usize },
    /// One `(frame, stage)` job on the stage executor.
    StageJob { frame: usize, stage: usize, unit: usize },
    /// Time spent blocked acquiring the `StageLease` unit lock.
    LeaseWait { frame: usize, stage: usize, unit: usize },
    /// One layer of the cluster walk on one stage unit.
    Layer { frame: usize, layer: usize, unit: usize },
    /// An interconnect transfer priced by the `Interconnect` log
    /// (instant: modeled cycles, not wall time).
    Transfer {
        frame: usize,
        index: usize,
        src: Option<usize>,
        dst: Option<usize>,
        bits: u64,
        cycles: u64,
    },
}

impl TraceKind {
    /// Chrome-trace event name (`cat.name` style, stable across PRs).
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::RequestQueued { .. } => "request.queued",
            TraceKind::RequestService { .. } => "request.service",
            TraceKind::RequestShed { .. } => "request.shed",
            TraceKind::RequestDeadlineMissed { .. } => "request.deadline_missed",
            TraceKind::EngineJob { .. } => "engine.job",
            TraceKind::StageJob { .. } => "stage.job",
            TraceKind::LeaseWait { .. } => "stage.lease_wait",
            TraceKind::Layer { .. } => "chip.layer",
            TraceKind::Transfer { .. } => "interconnect.transfer",
        }
    }

    /// Chrome-trace category.
    pub fn category(&self) -> &'static str {
        match self {
            TraceKind::RequestQueued { .. }
            | TraceKind::RequestService { .. }
            | TraceKind::RequestShed { .. }
            | TraceKind::RequestDeadlineMissed { .. } => "request",
            TraceKind::EngineJob { .. } => "engine",
            TraceKind::StageJob { .. } | TraceKind::LeaseWait { .. } => "stage",
            TraceKind::Layer { .. } => "chip",
            TraceKind::Transfer { .. } => "interconnect",
        }
    }

    /// Deterministic ordering key: depends only on the event's logical
    /// identity (never on wall-clock time or thread id), so sorted
    /// event streams are comparable across worker counts.
    pub fn sort_key(&self) -> (u8, usize, usize, usize, u64) {
        match *self {
            TraceKind::RequestQueued { request } => (0, request, 0, 0, 0),
            TraceKind::RequestService { request } => (1, request, 0, 0, 0),
            TraceKind::EngineJob { frame } => (2, frame, 0, 0, 0),
            TraceKind::StageJob { frame, stage, unit } => (3, frame, stage, unit, 0),
            TraceKind::LeaseWait { frame, stage, unit } => (4, frame, stage, unit, 0),
            TraceKind::Layer { frame, layer, unit } => (5, frame, layer, unit, 0),
            TraceKind::Transfer { frame, index, bits, .. } => (6, frame, index, 0, bits),
            // New tags append after the existing ones so historical
            // sort orders stay stable.
            TraceKind::RequestShed { request } => (7, request, 0, 0, 0),
            TraceKind::RequestDeadlineMissed { request } => (8, request, 0, 0, 0),
        }
    }
}

/// One recorded event: a span (`dur > 0`) or an instant (`dur == 0`),
/// stamped relative to the sink's epoch.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub kind: TraceKind,
    /// Offset from the sink epoch.
    pub start: Duration,
    /// Span length; zero for instants.
    pub dur: Duration,
    /// Recording thread's track id (Chrome `tid`). Not part of the
    /// deterministic identity — scheduling decides it.
    pub track: usize,
}

struct SinkShared {
    epoch: Instant,
    shards: Vec<Mutex<Vec<TraceEvent>>>,
    cap: usize,
    len: AtomicUsize,
    dropped: AtomicUsize,
}

/// Handle to a trace buffer, cheap to clone and send across threads.
/// `TraceSink::disabled()` (the default) is a no-op behind the same
/// API — every method short-circuits without touching a clock or lock.
#[derive(Clone, Default)]
pub struct TraceSink {
    shared: Option<Arc<SinkShared>>,
}

fn next_track() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static TRACK: usize = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TRACK.with(|t| *t)
}

impl TraceSink {
    /// An enabled sink with the default event capacity.
    pub fn enabled() -> Self {
        Self::enabled_with_capacity(DEFAULT_CAP)
    }

    /// An enabled sink retaining at most `cap` events; overflow counts
    /// into [`TraceSink::dropped`] instead of allocating.
    pub fn enabled_with_capacity(cap: usize) -> Self {
        TraceSink {
            shared: Some(Arc::new(SinkShared {
                epoch: Instant::now(),
                shards: (0..SHARDS).map(|_| Mutex::new(Vec::new())).collect(),
                cap: cap.max(1),
                len: AtomicUsize::new(0),
                dropped: AtomicUsize::new(0),
            })),
        }
    }

    /// The no-op sink (same as `Default`).
    pub fn disabled() -> Self {
        TraceSink { shared: None }
    }

    pub fn is_enabled(&self) -> bool {
        self.shared.is_some()
    }

    /// Current offset from the sink epoch, or `None` when disabled —
    /// the idiom `let t = sink.now(); ...; sink.span(kind, t)` costs
    /// nothing on the disabled path.
    pub fn now(&self) -> Option<Duration> {
        self.shared.as_ref().map(|s| s.epoch.elapsed())
    }

    /// Record a span from `start` (a value from [`TraceSink::now`]) to
    /// the current instant. No-op when disabled or `start` is `None`.
    pub fn span(&self, kind: TraceKind, start: Option<Duration>) {
        if let (Some(shared), Some(start)) = (self.shared.as_deref(), start) {
            let end = shared.epoch.elapsed();
            self.push(TraceEvent {
                kind,
                start,
                dur: end.saturating_sub(start),
                track: next_track(),
            });
        }
    }

    /// Record a span with both endpoints supplied (offsets from the
    /// sink epoch), e.g. timestamps captured on another thread.
    pub fn span_at(&self, kind: TraceKind, start: Duration, end: Duration) {
        if self.shared.is_some() {
            self.push(TraceEvent { kind, start, dur: end.saturating_sub(start), track: next_track() });
        }
    }

    /// Record an instantaneous event at the current time.
    pub fn instant(&self, kind: TraceKind) {
        if let Some(shared) = self.shared.as_deref() {
            let at = shared.epoch.elapsed();
            self.push(TraceEvent { kind, start: at, dur: Duration::ZERO, track: next_track() });
        }
    }

    fn push(&self, ev: TraceEvent) {
        let shared = match self.shared.as_deref() {
            Some(s) => s,
            None => return,
        };
        if shared.len.fetch_add(1, Ordering::Relaxed) >= shared.cap {
            shared.len.fetch_sub(1, Ordering::Relaxed);
            shared.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let shard = ev.track % SHARDS;
        shared.shards[shard].lock().unwrap().push(ev);
    }

    /// Events dropped at the capacity bound.
    pub fn dropped(&self) -> usize {
        self.shared.as_deref().map_or(0, |s| s.dropped.load(Ordering::Relaxed))
    }

    /// Merge all shards and sort by the deterministic
    /// [`TraceKind::sort_key`] — the canonical event stream used by the
    /// exporters and the determinism tests.
    pub fn events(&self) -> Vec<TraceEvent> {
        let shared = match self.shared.as_deref() {
            Some(s) => s,
            None => return Vec::new(),
        };
        let mut out = Vec::new();
        for shard in &shared.shards {
            out.extend(shard.lock().unwrap().iter().cloned());
        }
        out.sort_by_key(|e| e.kind.sort_key());
        out
    }

    /// Drop all recorded events (capacity and drop counter reset too).
    pub fn clear(&self) {
        if let Some(shared) = self.shared.as_deref() {
            for shard in &shared.shards {
                shard.lock().unwrap().clear();
            }
            shared.len.store(0, Ordering::Relaxed);
            shared.dropped.store(0, Ordering::Relaxed);
        }
    }
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.shared.as_deref() {
            Some(s) => f
                .debug_struct("TraceSink")
                .field("enabled", &true)
                .field("events", &s.len.load(Ordering::Relaxed))
                .field("dropped", &s.dropped.load(Ordering::Relaxed))
                .finish(),
            None => f.debug_struct("TraceSink").field("enabled", &false).finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_is_inert() {
        let sink = TraceSink::disabled();
        assert!(!sink.is_enabled());
        assert_eq!(sink.now(), None);
        sink.span(TraceKind::EngineJob { frame: 0 }, sink.now());
        sink.instant(TraceKind::EngineJob { frame: 1 });
        assert!(sink.events().is_empty());
        assert_eq!(sink.dropped(), 0);
    }

    #[test]
    fn spans_and_instants_record_and_sort_deterministically() {
        let sink = TraceSink::enabled();
        // Record out of logical order; events() must sort by identity.
        sink.instant(TraceKind::Transfer {
            frame: 1,
            index: 0,
            src: None,
            dst: Some(0),
            bits: 64,
            cycles: 2,
        });
        let t = sink.now();
        sink.span(TraceKind::StageJob { frame: 0, stage: 1, unit: 0 }, t);
        let t = sink.now();
        sink.span(TraceKind::StageJob { frame: 0, stage: 0, unit: 0 }, t);
        let ev = sink.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].kind, TraceKind::StageJob { frame: 0, stage: 0, unit: 0 });
        assert_eq!(ev[1].kind, TraceKind::StageJob { frame: 0, stage: 1, unit: 0 });
        assert_eq!(ev[2].kind.name(), "interconnect.transfer");
        assert_eq!(ev[2].dur, Duration::ZERO);
    }

    #[test]
    fn capacity_bound_counts_drops() {
        let sink = TraceSink::enabled_with_capacity(2);
        for frame in 0..5 {
            sink.instant(TraceKind::EngineJob { frame });
        }
        assert_eq!(sink.events().len(), 2);
        assert_eq!(sink.dropped(), 3);
        sink.clear();
        assert!(sink.events().is_empty());
        assert_eq!(sink.dropped(), 0);
        sink.instant(TraceKind::EngineJob { frame: 9 });
        assert_eq!(sink.events().len(), 1);
    }

    #[test]
    fn clones_share_the_buffer() {
        let sink = TraceSink::enabled();
        let handle = sink.clone();
        handle.instant(TraceKind::EngineJob { frame: 3 });
        assert_eq!(sink.events().len(), 1);
    }

    #[test]
    fn concurrent_recording_keeps_every_event() {
        let sink = TraceSink::enabled();
        std::thread::scope(|scope| {
            for w in 0..4 {
                let sink = sink.clone();
                scope.spawn(move || {
                    for i in 0..100 {
                        let t = sink.now();
                        sink.span(TraceKind::EngineJob { frame: w * 100 + i }, t);
                    }
                });
            }
        });
        let ev = sink.events();
        assert_eq!(ev.len(), 400);
        // Sorted by frame regardless of interleaving.
        for (i, e) in ev.iter().enumerate() {
            assert_eq!(e.kind, TraceKind::EngineJob { frame: i });
        }
    }
}
