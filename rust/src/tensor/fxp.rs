//! Fixed-point arithmetic matching the paper's datapath (Fig 16):
//! 8-bit FXP weights, 8-bit FXP membrane potential, 16-bit accumulators.
//!
//! Because SNN activations are binary spikes, a "multiply" is a gated add
//! of the 8-bit weight into a 16-bit partial sum — exactly what the gated
//! computation element in the PE does. The quantization scheme is a single
//! per-layer power-free affine scale (no zero point: weights are symmetric
//! around 0), shared with the python export path.

/// Saturate an i32 into i8 (8-bit FXP storage, e.g. membrane potential).
#[inline]
pub fn sat_i8(v: i32) -> i8 {
    v.clamp(i8::MIN as i32, i8::MAX as i32) as i8
}

/// Saturate an i32 into i16 (the PE's 16-bit accumulator registers).
#[inline]
pub fn sat_i16(v: i32) -> i16 {
    v.clamp(i16::MIN as i32, i16::MAX as i32) as i16
}

/// An 8-bit fixed-point value with an associated scale: `real = q * scale`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Fxp8 {
    /// Quantized value.
    pub q: i8,
    /// Scale (real units per LSB).
    pub scale: f32,
}

impl Fxp8 {
    /// Quantize a real value at the given scale (round-to-nearest,
    /// saturating).
    pub fn quantize(real: f32, scale: f32) -> Self {
        let q = (real / scale).round() as i32;
        Fxp8 { q: sat_i8(q), scale }
    }

    /// Recover the real value.
    pub fn dequantize(self) -> f32 {
        self.q as f32 * self.scale
    }
}

/// Per-layer quantization parameters shared between the float model and the
/// integer datapath.
///
/// The LIF threshold (0.5) and leak (0.25) of the paper live in the
/// *normalized* (post-tdBN) domain; on the integer datapath the threshold
/// becomes `vth_q = round(0.5 / scale)` and the leak is an exact arithmetic
/// right shift by 2 (×0.25) — this is why the paper picked those constants
/// ("for a simple hardware implementation").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    /// Real units per weight LSB.
    pub scale: f32,
    /// Integer firing threshold (`round(0.5/scale)`).
    pub vth_q: i32,
}

impl QuantParams {
    /// LIF threshold in the normalized domain (§II-A).
    pub const VTH_REAL: f32 = 0.5;
    /// LIF leak factor (×0.25 = `>> 2`).
    pub const LEAK_SHIFT: u32 = 2;

    /// Derive per-layer parameters from the max |weight| after BN folding.
    ///
    /// The scale is chosen so weights span i8 and the integer threshold
    /// stays comfortably inside the 8-bit membrane range (≤ 96), matching
    /// the paper's 8-bit Vmem storage.
    pub fn from_weight_absmax(absmax: f32) -> Self {
        let mut scale = (absmax / 127.0).max(1e-8);
        // Keep vth_q ≤ 96 so potentials near threshold fit 8-bit storage.
        let min_scale = Self::VTH_REAL / 96.0;
        if scale < min_scale {
            scale = min_scale;
        }
        let vth_q = (Self::VTH_REAL / scale).round() as i32;
        QuantParams { scale, vth_q }
    }

    /// Quantize one weight.
    pub fn quantize_weight(&self, w: f32) -> i8 {
        sat_i8((w / self.scale).round() as i32)
    }

    /// Quantize a bias into the 16-bit accumulator domain.
    pub fn quantize_bias(&self, b: f32) -> i16 {
        sat_i16((b / self.scale).round() as i32)
    }

    /// Exact integer leak: `v * 0.25` as an arithmetic shift with
    /// round-toward-zero, mirroring the RTL (sign-preserving).
    #[inline]
    pub fn leak(v: i32) -> i32 {
        // Arithmetic shift rounds toward -inf; hardware uses truncation
        // toward zero for symmetric decay, so compensate negatives.
        if v >= 0 {
            v >> Self::LEAK_SHIFT
        } else {
            -((-v) >> Self::LEAK_SHIFT)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::run_prop;

    #[test]
    fn sat_bounds() {
        assert_eq!(sat_i8(1000), 127);
        assert_eq!(sat_i8(-1000), -128);
        assert_eq!(sat_i8(5), 5);
        assert_eq!(sat_i16(40_000), 32_767);
        assert_eq!(sat_i16(-40_000), -32_768);
    }

    #[test]
    fn quantize_roundtrip_small_error() {
        let qp = QuantParams::from_weight_absmax(1.0);
        for w in [-1.0f32, -0.5, -0.1, 0.0, 0.3, 0.99] {
            let q = qp.quantize_weight(w);
            let err = (q as f32 * qp.scale - w).abs();
            assert!(err <= qp.scale / 2.0 + 1e-6, "w={w} err={err}");
        }
    }

    #[test]
    fn vth_q_in_8bit_range() {
        for absmax in [0.01f32, 0.1, 0.5, 1.0, 4.0, 10.0] {
            let qp = QuantParams::from_weight_absmax(absmax);
            assert!(qp.vth_q > 0 && qp.vth_q <= 96, "absmax={absmax} vth={}", qp.vth_q);
        }
    }

    #[test]
    fn leak_truncates_toward_zero() {
        assert_eq!(QuantParams::leak(7), 1);
        assert_eq!(QuantParams::leak(-7), -1);
        assert_eq!(QuantParams::leak(8), 2);
        assert_eq!(QuantParams::leak(-8), -2);
        assert_eq!(QuantParams::leak(3), 0);
        assert_eq!(QuantParams::leak(-3), 0);
    }

    #[test]
    fn fxp8_quantize_dequantize() {
        let v = Fxp8::quantize(0.37, 0.01);
        assert_eq!(v.q, 37);
        assert!((v.dequantize() - 0.37).abs() < 1e-6);
    }

    #[test]
    fn prop_leak_magnitude_shrinks() {
        run_prop("fxp/leak-shrinks", |g| {
            let v = g.i64(-1 << 20, 1 << 20) as i32;
            let l = QuantParams::leak(v);
            assert!(l.abs() <= v.abs() / 4 + 1);
            assert!(l.signum() == 0 || l.signum() == v.signum());
        });
    }
}
