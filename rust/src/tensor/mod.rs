//! Dense tensor substrate.
//!
//! The accelerator operates on small integer domains — binary spikes,
//! 8-bit fixed-point weights and membrane potentials, 16-bit accumulators —
//! so the tensor type is a plain row-major container generic over the
//! element. Layout is `(C, H, W)` for feature maps and `(K, C, Kh, Kw)`
//! for kernels; the time dimension is kept as an explicit `Vec<Tensor>`
//! because the hardware streams time steps (it never holds a T-major
//! tensor).

pub mod fxp;

pub use fxp::{sat_i16, sat_i8, Fxp8, QuantParams};

/// Row-major 3-D tensor `(c, h, w)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor<T> {
    /// Channels.
    pub c: usize,
    /// Height (rows).
    pub h: usize,
    /// Width (columns).
    pub w: usize,
    /// Row-major data, `len == c*h*w`.
    pub data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-initialized tensor.
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        Tensor { c, h, w, data: vec![T::default(); c * h * w] }
    }

    /// Build from existing data (length must match).
    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), c * h * w, "tensor shape/data mismatch");
        Tensor { c, h, w, data }
    }

    /// Total element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor is empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Flat index of `(c, y, x)`.
    #[inline]
    pub fn idx(&self, c: usize, y: usize, x: usize) -> usize {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        (c * self.h + y) * self.w + x
    }

    /// Element access.
    #[inline]
    pub fn get(&self, c: usize, y: usize, x: usize) -> T {
        self.data[self.idx(c, y, x)]
    }

    /// Mutable element access.
    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: T) {
        let i = self.idx(c, y, x);
        self.data[i] = v;
    }

    /// Element access with **replicate** (clamp-to-edge) boundary padding —
    /// the paper's block-convolution padding mode (§II-B).
    #[inline]
    pub fn get_replicate(&self, c: usize, y: isize, x: isize) -> T {
        let yy = y.clamp(0, self.h as isize - 1) as usize;
        let xx = x.clamp(0, self.w as isize - 1) as usize;
        self.get(c, yy, xx)
    }

    /// One channel plane as a slice.
    pub fn channel(&self, c: usize) -> &[T] {
        let hw = self.h * self.w;
        &self.data[c * hw..(c + 1) * hw]
    }

    /// Extract the sub-tile `[y0, y0+th) × [x0, x0+tw)` over all channels.
    /// Out-of-bounds reads use replicate padding so edge tiles are full
    /// size, matching the hardware's fixed 32×18 PE tile.
    pub fn tile_replicate(&self, y0: isize, x0: isize, th: usize, tw: usize) -> Tensor<T> {
        let mut out = Tensor::zeros(self.c, th, tw);
        for c in 0..self.c {
            for ty in 0..th {
                for tx in 0..tw {
                    let v = self.get_replicate(c, y0 + ty as isize, x0 + tx as isize);
                    out.set(c, ty, tx, v);
                }
            }
        }
        out
    }
}

impl Tensor<u8> {
    /// Fraction of zero elements (activation sparsity, §IV-E).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let zeros = self.data.iter().filter(|&&v| v == 0).count();
        zeros as f64 / self.data.len() as f64
    }

    /// Number of nonzero (fired) elements.
    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0).count()
    }
}

/// Row-major 4-D kernel tensor `(k, c, kh, kw)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Kernel4<T> {
    /// Output channels.
    pub k: usize,
    /// Input channels.
    pub c: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Row-major data, `len == k*c*kh*kw`.
    pub data: Vec<T>,
}

impl<T: Copy + Default> Kernel4<T> {
    /// Zero-initialized kernel.
    pub fn zeros(k: usize, c: usize, kh: usize, kw: usize) -> Self {
        Kernel4 { k, c, kh, kw, data: vec![T::default(); k * c * kh * kw] }
    }

    /// Build from existing data (length must match).
    pub fn from_vec(k: usize, c: usize, kh: usize, kw: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), k * c * kh * kw, "kernel shape/data mismatch");
        Kernel4 { k, c, kh, kw, data }
    }

    /// Flat index of `(k, c, i, j)`.
    #[inline]
    pub fn idx(&self, k: usize, c: usize, i: usize, j: usize) -> usize {
        debug_assert!(k < self.k && c < self.c && i < self.kh && j < self.kw);
        ((k * self.c + c) * self.kh + i) * self.kw + j
    }

    /// Element access.
    #[inline]
    pub fn get(&self, k: usize, c: usize, i: usize, j: usize) -> T {
        self.data[self.idx(k, c, i, j)]
    }

    /// Mutable element access.
    #[inline]
    pub fn set(&mut self, k: usize, c: usize, i: usize, j: usize, v: T) {
        let idx = self.idx(k, c, i, j);
        self.data[idx] = v;
    }

    /// The `(kh, kw)` plane for `(k, c)` as a slice.
    pub fn plane(&self, k: usize, c: usize) -> &[T] {
        let n = self.kh * self.kw;
        let base = (k * self.c + c) * n;
        &self.data[base..base + n]
    }
}

impl Kernel4<i8> {
    /// Fraction of zero weights (weight sparsity after pruning, Fig 3).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let zeros = self.data.iter().filter(|&&v| v == 0).count();
        zeros as f64 / self.data.len() as f64
    }

    /// Number of nonzero weights.
    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::run_prop;

    #[test]
    fn indexing_roundtrip() {
        let mut t: Tensor<i32> = Tensor::zeros(3, 4, 5);
        t.set(2, 3, 4, 99);
        assert_eq!(t.get(2, 3, 4), 99);
        assert_eq!(t.data[t.idx(2, 3, 4)], 99);
    }

    #[test]
    fn replicate_padding_clamps() {
        let t = Tensor::from_vec(1, 2, 2, vec![1u8, 2, 3, 4]);
        assert_eq!(t.get_replicate(0, -1, -1), 1);
        assert_eq!(t.get_replicate(0, -5, 1), 2);
        assert_eq!(t.get_replicate(0, 5, 5), 4);
        assert_eq!(t.get_replicate(0, 1, -3), 3);
    }

    #[test]
    fn tile_replicate_interior_matches_get() {
        let mut t: Tensor<u8> = Tensor::zeros(2, 6, 6);
        for (i, v) in t.data.iter_mut().enumerate() {
            *v = (i % 251) as u8;
        }
        let tile = t.tile_replicate(1, 2, 3, 3);
        for c in 0..2 {
            for y in 0..3 {
                for x in 0..3 {
                    assert_eq!(tile.get(c, y, x), t.get(c, 1 + y, 2 + x));
                }
            }
        }
    }

    #[test]
    fn sparsity_counts() {
        let t = Tensor::from_vec(1, 1, 4, vec![0u8, 1, 0, 1]);
        assert_eq!(t.sparsity(), 0.5);
        assert_eq!(t.count_nonzero(), 2);
    }

    #[test]
    fn kernel_plane_slices() {
        let mut k: Kernel4<i8> = Kernel4::zeros(2, 3, 3, 3);
        k.set(1, 2, 0, 1, 7);
        let plane = k.plane(1, 2);
        assert_eq!(plane[1], 7);
    }

    #[test]
    fn prop_tile_replicate_edges_clamp() {
        run_prop("tensor/tile-replicate-clamps", |g| {
            let c = g.usize(1, 4);
            let h = g.usize(1, 8);
            let w = g.usize(1, 8);
            let data = g.vec(c * h * w, |g| g.rng().next_u32() as u8);
            let t = Tensor::from_vec(c, h, w, data);
            let y0 = g.i64(-3, h as i64) as isize;
            let x0 = g.i64(-3, w as i64) as isize;
            let tile = t.tile_replicate(y0, x0, 4, 4);
            for cc in 0..c {
                for ty in 0..4usize {
                    for tx in 0..4usize {
                        assert_eq!(
                            tile.get(cc, ty, tx),
                            t.get_replicate(cc, y0 + ty as isize, x0 + tx as isize)
                        );
                    }
                }
            }
        });
    }
}
