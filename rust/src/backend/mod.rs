//! Unified execution backends — one trait, three engines.
//!
//! The repo has three ways to compute a frame's head accumulator, all
//! bit-identical by construction:
//!
//! - [`GoldenBackend`] — the functional golden model
//!   ([`crate::ref_impl::SnnForward`]), compressed spike maps end-to-end;
//! - [`CycleSimBackend`] — the cycle-level accelerator simulator
//!   ([`crate::accel::controller::SystemController`]), which additionally
//!   reports per-layer/per-core cycle counts;
//! - [`PjrtBackend`] — the AOT-compiled HLO graph on the PJRT CPU client
//!   ([`crate::runtime::SnnExecutable`], behind the `pjrt` feature).
//!
//! [`SnnBackend`] is the serving-path abstraction over them: `run_frame`
//! plus capability and metrics hooks. The coordinator's streaming engine
//! ([`crate::coordinator::engine`]) schedules frames onto any backend
//! without knowing which one it drives; expensive preprocessing (weight
//! validation, bit-mask compression of the kernel planes) happens **once**
//! at backend construction and is shared across frames and worker threads
//! behind `Arc`s.

pub mod cyclesim;
pub mod golden;
pub mod pjrt;
pub mod select;

pub use cyclesim::CycleSimBackend;
pub use golden::GoldenBackend;
pub use pjrt::PjrtBackend;
pub use select::{AutoSelectPolicy, RequestClass};

use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::BTreeMap;

/// What a backend can do beyond producing the head accumulator.
#[derive(Clone, Copy, Debug)]
pub struct BackendCaps {
    /// `run_frame` may be called concurrently from worker threads. When
    /// false the engine keeps every frame on the coordinator thread.
    pub parallel: bool,
    /// Fills per-layer `input_sparsity` / `spikes_out` observations.
    pub reports_sparsity: bool,
    /// Fills per-layer (and per-core) cycle counts.
    pub reports_cycles: bool,
}

/// Per-frame execution options.
#[derive(Clone, Copy, Debug, Default)]
pub struct FrameOptions {
    /// Collect per-layer observations (sparsity popcounts, cycles) into
    /// [`BackendFrame::layers`]. Off for the plain detection path.
    pub collect_stats: bool,
}

/// One layer's observations from a backend run. Which fields are
/// populated depends on [`BackendCaps`]; unreported fields are zero.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerObservation {
    /// Mean fraction of zero inputs over the executed conv time steps.
    pub input_sparsity: f64,
    /// Spikes emitted by the layer (popcount over output time steps).
    pub spikes_out: u64,
    /// Layer makespan in cycles (cycle-reporting backends).
    pub cycles: u64,
    /// Dense-baseline makespan.
    pub dense_cycles: u64,
    /// Per-core cycle counters (multi-core cycle simulation).
    pub core_cycles: Vec<u64>,
    /// Unique row patterns built by the product-sparsity datapath (zero
    /// on the bit-mask datapath and non-cycle backends).
    pub patterns_unique: u64,
    /// MACs replayed from an already-built pattern instead of recomputed.
    pub macs_reused: u64,
    /// Output rows whose inputs were unchanged from the previous time
    /// step (temporal-delta datapath only).
    pub rows_unchanged: u64,
    /// Tile planes whose reuse forest was served from the cross-tile
    /// pattern cache instead of re-mined (temporal-delta datapath only).
    pub cache_hits: u64,
    /// MACs replayed from the previous time step's accumulator deltas
    /// (temporal-delta datapath only; disjoint from `macs_reused`).
    pub macs_reused_temporal: u64,
}

/// One frame's result: the raw integer head accumulator plus whatever
/// observations the backend reports. Decoding/NMS stay in the
/// coordinator — backends end at the representation boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct BackendFrame {
    /// Head accumulator `(c, gh, gw)`, summed over time steps.
    pub head_acc: Tensor<i32>,
    /// Per-layer observations (empty unless
    /// [`FrameOptions::collect_stats`] and the backend reports any).
    pub layers: BTreeMap<String, LayerObservation>,
}

impl BackendFrame {
    /// Frame makespan in cycles summed over layers (0 for backends that
    /// don't report cycles).
    pub fn total_cycles(&self) -> u64 {
        self.layers.values().map(|l| l.cycles).sum()
    }

    /// Total spikes emitted across all layers.
    pub fn total_spikes(&self) -> u64 {
        self.layers.values().map(|l| l.spikes_out).sum()
    }
}

/// A frame-execution engine: the one interface the serving path sees.
///
/// Implementations must be cheap to *call* — all per-model preprocessing
/// (validation, weight compression) belongs in the constructor so a
/// backend can be shared across worker threads behind an `Arc` and run
/// frames with nothing but per-frame state.
pub trait SnnBackend: Send + Sync {
    /// Stable identifier (`golden`, `cyclesim`, `pjrt`).
    fn name(&self) -> &'static str;

    /// Static capabilities.
    fn caps(&self) -> BackendCaps;

    /// Execute one RGB frame `(3, h, w)` and return the head accumulator
    /// (+ observations per `opts`).
    fn run_frame(&self, image: &Tensor<u8>, opts: &FrameOptions) -> Result<BackendFrame>;
}

/// CLI-selectable backend kind (`--backend {golden,cyclesim,pjrt,cluster}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Functional golden model.
    Golden,
    /// Cycle-level accelerator simulator.
    CycleSim,
    /// PJRT-compiled AOT graph.
    Pjrt,
    /// Multi-chip cluster ([`crate::cluster::ChipCluster`]). When the
    /// pipeline sets a `--pipeline N` window, cluster frames route
    /// through the wall-clock stage executor
    /// (`crate::coordinator::stage_exec`) instead of monolithic
    /// `run_frame` calls — same bits, overlapped stages.
    Cluster,
}

impl BackendKind {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "golden" | "ref" => Some(BackendKind::Golden),
            "cyclesim" | "cycle-sim" | "sim" => Some(BackendKind::CycleSim),
            "pjrt" => Some(BackendKind::Pjrt),
            "cluster" => Some(BackendKind::Cluster),
            _ => None,
        }
    }

    /// Canonical spelling.
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::Golden => "golden",
            BackendKind::CycleSim => "cyclesim",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Cluster => "cluster",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses_cli_spellings() {
        assert_eq!(BackendKind::parse("golden"), Some(BackendKind::Golden));
        assert_eq!(BackendKind::parse("cyclesim"), Some(BackendKind::CycleSim));
        assert_eq!(BackendKind::parse("sim"), Some(BackendKind::CycleSim));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("cluster"), Some(BackendKind::Cluster));
        assert_eq!(BackendKind::parse("tpu"), None);
        assert_eq!(BackendKind::CycleSim.label(), "cyclesim");
        assert_eq!(BackendKind::Cluster.label(), "cluster");
    }

    #[test]
    fn backend_frame_aggregates() {
        let mut layers = BTreeMap::new();
        layers.insert(
            "a".to_string(),
            LayerObservation { cycles: 10, spikes_out: 3, ..Default::default() },
        );
        layers.insert(
            "b".to_string(),
            LayerObservation { cycles: 5, spikes_out: 4, ..Default::default() },
        );
        let f = BackendFrame { head_acc: Tensor::zeros(1, 1, 1), layers };
        assert_eq!(f.total_cycles(), 15);
        assert_eq!(f.total_spikes(), 7);
    }
}
