//! PJRT backend: the AOT-compiled HLO graph on the PJRT CPU client.
//!
//! Only available in `pjrt`-feature builds; on stub builds
//! [`PjrtBackend::load`] errors (like [`SnnExecutable::load`]) and the
//! pipeline falls back to the golden model, which is bit-identical to the
//! exported graph by construction.
//!
//! The executable sits behind a `Mutex` because the PJRT client is not
//! known to be thread-safe; accordingly [`BackendCaps::parallel`] is
//! false and the streaming engine keeps PJRT frames on the coordinator
//! thread instead of fanning them out.

use super::{BackendCaps, BackendFrame, FrameOptions, SnnBackend};
use crate::runtime::SnnExecutable;
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

/// The PJRT executable behind the [`SnnBackend`] interface.
pub struct PjrtBackend {
    exe: Mutex<SnnExecutable>,
}

impl PjrtBackend {
    /// Static capabilities (also returned by [`SnnBackend::caps`]) — the
    /// auto-select policy reads these without constructing a backend.
    pub const CAPS: BackendCaps =
        BackendCaps { parallel: false, reports_sparsity: false, reports_cycles: false };

    /// Wrap an already-loaded executable.
    pub fn new(exe: SnnExecutable) -> PjrtBackend {
        PjrtBackend { exe: Mutex::new(exe) }
    }

    /// Load and compile an HLO-text artifact (errors on stub builds).
    pub fn load(
        hlo_path: &Path,
        input_shape: (usize, usize, usize),
        head_shape: (usize, usize, usize),
    ) -> Result<PjrtBackend> {
        Ok(PjrtBackend::new(SnnExecutable::load(hlo_path, input_shape, head_shape)?))
    }

    /// Platform string of the underlying client.
    pub fn platform(&self) -> String {
        self.exe.lock().expect("pjrt lock").platform()
    }
}

impl SnnBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn caps(&self) -> BackendCaps {
        Self::CAPS
    }

    fn run_frame(&self, image: &Tensor<u8>, _opts: &FrameOptions) -> Result<BackendFrame> {
        let head_acc = self.exe.lock().expect("pjrt lock").run(image)?;
        Ok(BackendFrame { head_acc, layers: BTreeMap::new() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_errors_without_artifact_or_runtime() {
        // Stub builds error on principle; real builds error on the
        // missing file. Either way: an error, never a silent fallback.
        assert!(PjrtBackend::load(Path::new("/nonexistent/x.hlo.txt"), (3, 192, 320), (40, 6, 10))
            .is_err());
    }
}
