//! Golden-model backend: the functional reference, compressed spike maps
//! end-to-end. This is the default serving backend — bit-identical to the
//! exported PJRT graph (whole-image convolution) or to the accelerator
//! (block convolution with the hardware tile), depending on the
//! [`ForwardOptions`] it is built with.

use super::{BackendCaps, BackendFrame, FrameOptions, LayerObservation, SnnBackend};
use crate::model::topology::NetworkSpec;
use crate::model::weights::ModelWeights;
use crate::ref_impl::{ForwardOptions, SnnForward};
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

/// The functional golden model behind the [`SnnBackend`] interface.
///
/// Weights are validated once at construction; the spec and weights live
/// behind `Arc`s shared with the pipeline and across worker threads, so
/// `run_frame` allocates only per-frame state.
pub struct GoldenBackend {
    net: Arc<NetworkSpec>,
    weights: Arc<ModelWeights>,
    opts: ForwardOptions,
}

impl GoldenBackend {
    /// Static capabilities (also returned by [`SnnBackend::caps`]) — the
    /// auto-select policy reads these without constructing a backend.
    pub const CAPS: BackendCaps =
        BackendCaps { parallel: true, reports_sparsity: true, reports_cycles: false };

    /// New backend; validates weights against the spec.
    pub fn new(
        net: Arc<NetworkSpec>,
        weights: Arc<ModelWeights>,
        opts: ForwardOptions,
    ) -> Result<GoldenBackend> {
        weights.validate_against(&net)?;
        Ok(GoldenBackend { net, weights, opts })
    }

    /// The forward options this backend runs with.
    pub fn forward_options(&self) -> ForwardOptions {
        self.opts
    }
}

impl SnnBackend for GoldenBackend {
    fn name(&self) -> &'static str {
        "golden"
    }

    fn caps(&self) -> BackendCaps {
        Self::CAPS
    }

    fn run_frame(&self, image: &Tensor<u8>, opts: &FrameOptions) -> Result<BackendFrame> {
        let fwd = SnnForward::new(&self.net, &self.weights, self.opts)?;
        let res = fwd.run(image)?;
        let layers: BTreeMap<String, LayerObservation> = if opts.collect_stats {
            res.stats
                .iter()
                .map(|(name, s)| {
                    (
                        name.clone(),
                        LayerObservation {
                            input_sparsity: s.input_sparsity,
                            spikes_out: s.spikes_out,
                            ..Default::default()
                        },
                    )
                })
                .collect()
        } else {
            BTreeMap::new()
        };
        Ok(BackendFrame { head_acc: res.head_acc, layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::topology::{Scale, TimeStepConfig};
    use crate::util::Rng;

    fn setup() -> (Arc<NetworkSpec>, Arc<ModelWeights>, Tensor<u8>) {
        let net = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        let mut w = ModelWeights::random(&net, 1.0, 40);
        w.prune_fine_grained(0.8);
        let mut rng = Rng::new(41);
        let n = net.input_c * net.input_h * net.input_w;
        let img = Tensor::from_vec(
            net.input_c,
            net.input_h,
            net.input_w,
            (0..n).map(|_| rng.next_u32() as u8).collect(),
        );
        (Arc::new(net), Arc::new(w), img)
    }

    #[test]
    fn matches_direct_golden_run() {
        let (net, w, img) = setup();
        let opts = ForwardOptions { block_tile: None, record_spikes: false };
        let be = GoldenBackend::new(net.clone(), w.clone(), opts).unwrap();
        let frame = be.run_frame(&img, &FrameOptions { collect_stats: true }).unwrap();
        let want = SnnForward::new(&net, &w, opts).unwrap().run(&img).unwrap();
        assert_eq!(frame.head_acc.data, want.head_acc.data);
        assert_eq!(frame.layers.len(), net.layers.len());
        for (name, obs) in &frame.layers {
            let s = want.stats.get(name).unwrap();
            assert_eq!(obs.input_sparsity, s.input_sparsity, "{name}");
            assert_eq!(obs.spikes_out, s.spikes_out, "{name}");
            assert_eq!(obs.cycles, 0, "golden reports no cycles");
        }
    }

    #[test]
    fn stats_off_leaves_layers_empty() {
        let (net, w, img) = setup();
        let be = GoldenBackend::new(net, w, ForwardOptions::default()).unwrap();
        let frame = be.run_frame(&img, &FrameOptions::default()).unwrap();
        assert!(frame.layers.is_empty());
        assert!(be.caps().parallel);
    }

    #[test]
    fn rejects_mismatched_weights() {
        let tiny = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        let full = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let w = ModelWeights::random(&tiny, 0.5, 42);
        assert!(GoldenBackend::new(Arc::new(full), Arc::new(w), ForwardOptions::default())
            .is_err());
    }
}
