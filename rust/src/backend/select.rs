//! Backend auto-select: a serving policy object that picks the execution
//! backend from [`BackendCaps`] and the current request load, instead of
//! a CLI flag (closes the ROADMAP "backend auto-select" item).
//!
//! The rules, in order:
//!
//! 1. A caller that wants hardware metrics gets the first cycle-reporting
//!    backend (the cluster when one is registered, else the cycle
//!    simulator).
//! 2. Under pressure — a deep queue, or the measured total-latency tail
//!    already past the SLO target — throughput wins: the first backend
//!    that can run frames concurrently **without** paying cycle
//!    accounting (the golden model).
//! 3. Under a shallow queue, single-frame latency wins: the PJRT engine
//!    when it is built (it cannot parallelize, but one compiled frame
//!    beats interpretation).
//! 4. Otherwise any parallel backend, else whatever is registered.
//!
//! The policy only reads [`SnnBackend::caps`] and [`SnnBackend::name`] —
//! registering a new backend (as the cluster subsystem does) requires no
//! policy change.

use super::{BackendCaps, SnnBackend};
use std::sync::Arc;

/// What the caller needs from the run.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestClass {
    /// The caller wants per-layer/per-core cycle counts.
    pub want_cycles: bool,
    /// Frames currently queued (the engine's back-pressure signal).
    pub pending: usize,
    /// The serving tail (measured total-latency p99) is already past
    /// the SLO target: treat the system as under pressure even when
    /// the queue reads shallow — backlog drains before the queue-depth
    /// signal catches up.
    pub tail_over_target: bool,
}

/// The auto-select policy.
#[derive(Clone, Copy, Debug)]
pub struct AutoSelectPolicy {
    /// Queue depth above which throughput beats single-frame latency.
    pub deep_queue: usize,
}

impl Default for AutoSelectPolicy {
    fn default() -> Self {
        AutoSelectPolicy { deep_queue: 4 }
    }
}

impl AutoSelectPolicy {
    /// Pick among candidate **descriptors** — `(name, caps)` pairs, which
    /// are statically known per backend kind (each backend exposes a
    /// `CAPS` const) — so callers can defer construction to the winning
    /// candidate only. First match wins, so the caller's registration
    /// order breaks ties. `None` only when `candidates` is empty.
    pub fn choose_desc(
        &self,
        candidates: &[(&str, BackendCaps)],
        req: &RequestClass,
    ) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        if req.want_cycles {
            if let Some(i) = candidates.iter().position(|(_, c)| c.reports_cycles) {
                return Some(i);
            }
        }
        if req.pending > self.deep_queue || req.tail_over_target {
            if let Some(i) = candidates.iter().position(|(_, c)| c.parallel && !c.reports_cycles)
            {
                return Some(i);
            }
        } else if let Some(i) = candidates.iter().position(|(n, _)| *n == "pjrt") {
            return Some(i);
        }
        candidates.iter().position(|(_, c)| c.parallel).or(Some(0))
    }

    /// [`Self::choose_desc`] over already-constructed backends.
    pub fn choose(
        &self,
        candidates: &[Arc<dyn SnnBackend>],
        req: &RequestClass,
    ) -> Option<Arc<dyn SnnBackend>> {
        let descs: Vec<(&str, BackendCaps)> =
            candidates.iter().map(|b| (b.name(), b.caps())).collect();
        self.choose_desc(&descs, req).map(|i| candidates[i].clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendCaps, BackendFrame, FrameOptions};
    use crate::tensor::Tensor;
    use anyhow::Result;
    use std::collections::BTreeMap;

    struct Fake {
        name: &'static str,
        caps: BackendCaps,
    }

    impl SnnBackend for Fake {
        fn name(&self) -> &'static str {
            self.name
        }

        fn caps(&self) -> BackendCaps {
            self.caps
        }

        fn run_frame(&self, image: &Tensor<u8>, _: &FrameOptions) -> Result<BackendFrame> {
            Ok(BackendFrame {
                head_acc: Tensor::zeros(image.c, image.h, image.w),
                layers: BTreeMap::new(),
            })
        }
    }

    fn dcaps(parallel: bool, cycles: bool) -> BackendCaps {
        BackendCaps { parallel, reports_sparsity: cycles, reports_cycles: cycles }
    }

    fn fake(name: &'static str, parallel: bool, cycles: bool) -> Arc<dyn SnnBackend> {
        Arc::new(Fake { name, caps: dcaps(parallel, cycles) })
    }

    fn fleet() -> Vec<Arc<dyn SnnBackend>> {
        vec![
            fake("pjrt", false, false),
            fake("golden", true, false),
            fake("cluster", true, true),
            fake("cyclesim", true, true),
        ]
    }

    #[test]
    fn cycle_requests_get_the_cycle_reporter() {
        let p = AutoSelectPolicy::default();
        let req = RequestClass { want_cycles: true, pending: 100, ..Default::default() };
        let got = p.choose(&fleet(), &req).unwrap();
        // First registered cycle reporter wins: the cluster.
        assert_eq!(got.name(), "cluster");
        // Without one registered, fall through to the load rules.
        let no_cycles = vec![fake("golden", true, false)];
        let req = RequestClass { want_cycles: true, ..Default::default() };
        let got = p.choose(&no_cycles, &req).unwrap();
        assert_eq!(got.name(), "golden");
    }

    #[test]
    fn deep_queue_prefers_throughput_shallow_prefers_pjrt() {
        let p = AutoSelectPolicy::default();
        let deep = p
            .choose(&fleet(), &RequestClass { pending: 16, ..Default::default() })
            .unwrap();
        assert_eq!(deep.name(), "golden", "deep queue: parallel + no cycle tax");
        let shallow = p
            .choose(&fleet(), &RequestClass { pending: 1, ..Default::default() })
            .unwrap();
        assert_eq!(shallow.name(), "pjrt", "shallow queue: compiled single-frame latency");
        // Shallow queue without PJRT built: first parallel backend.
        let no_pjrt: Vec<Arc<dyn SnnBackend>> = fleet().into_iter().skip(1).collect();
        let got = p
            .choose(&no_pjrt, &RequestClass { pending: 1, ..Default::default() })
            .unwrap();
        assert_eq!(got.name(), "golden");
    }

    #[test]
    fn tail_over_target_forces_throughput_at_shallow_pending() {
        let p = AutoSelectPolicy::default();
        // Queue reads shallow, but the measured tail is already past the
        // SLO target: the throughput backend wins over PJRT.
        let req = RequestClass { pending: 0, tail_over_target: true, ..Default::default() };
        let got = p.choose(&fleet(), &req).unwrap();
        assert_eq!(got.name(), "golden", "tail pressure overrides the shallow-queue rule");
        // want_cycles still takes precedence over tail pressure.
        let req = RequestClass { want_cycles: true, tail_over_target: true, ..Default::default() };
        assert_eq!(p.choose(&fleet(), &req).unwrap().name(), "cluster");
    }

    #[test]
    fn choose_desc_picks_without_construction() {
        let p = AutoSelectPolicy::default();
        let descs = [
            ("pjrt", dcaps(false, false)),
            ("golden", dcaps(true, false)),
            ("cluster", dcaps(true, true)),
        ];
        let pick = |want_cycles, pending| {
            let req = RequestClass { want_cycles, pending, ..Default::default() };
            p.choose_desc(&descs, &req).map(|i| descs[i].0)
        };
        assert_eq!(pick(true, 0), Some("cluster"));
        assert_eq!(pick(false, 100), Some("golden"));
        assert_eq!(pick(false, 0), Some("pjrt"));
        assert_eq!(p.choose_desc(&[], &RequestClass::default()), None);
    }

    #[test]
    fn empty_and_degenerate_fleets() {
        let p = AutoSelectPolicy::default();
        assert!(p.choose(&[], &RequestClass::default()).is_none());
        // Only a sequential backend registered: still chosen.
        let seq = vec![fake("pjrt", false, false)];
        let req = RequestClass { want_cycles: true, pending: 100, ..Default::default() };
        let got = p.choose(&seq, &req).unwrap();
        assert_eq!(got.name(), "pjrt");
    }
}
