//! Cycle-simulator backend: the whole network executed layer by layer on
//! the cycle-level [`SystemController`], compressed spike maps threaded
//! between layers (CSP shortcut/concat wiring included). Bit-exact
//! against the golden model run with the hardware block tile, and the
//! only backend that reports cycle counts — per layer and per simulated
//! core (`AccelConfig::num_cores`).
//!
//! The per-`(k, c)` bit-mask weight planes are compressed **once** at
//! construction and shared across frames and worker threads behind an
//! `Arc` — the serving path never re-compresses weights per frame.

use super::{BackendCaps, BackendFrame, FrameOptions, LayerObservation, SnnBackend};
use crate::accel::controller::{LayerInput, SystemController};
use crate::config::AccelConfig;
use crate::model::topology::{ConvKind, NetworkSpec};
use crate::model::weights::ModelWeights;
use crate::sparse::{bitmask::compress_kernel4, BitMaskKernel, SpikeMap};
use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// The cycle-level simulator behind the [`SnnBackend`] interface.
pub struct CycleSimBackend {
    net: Arc<NetworkSpec>,
    weights: Arc<ModelWeights>,
    cfg: AccelConfig,
    /// Per-layer compressed weight planes, built once.
    planes: Arc<BTreeMap<String, Vec<BitMaskKernel>>>,
}

impl CycleSimBackend {
    /// Static capabilities (also returned by [`SnnBackend::caps`]) — the
    /// auto-select policy reads these without constructing a backend.
    pub const CAPS: BackendCaps =
        BackendCaps { parallel: true, reports_sparsity: true, reports_cycles: true };

    /// New backend bound to a hardware configuration; validates weights
    /// and compresses every layer's kernel into bit-mask planes.
    pub fn new(
        net: Arc<NetworkSpec>,
        weights: Arc<ModelWeights>,
        cfg: AccelConfig,
    ) -> Result<CycleSimBackend> {
        weights.validate_against(&net)?;
        let planes: BTreeMap<String, Vec<BitMaskKernel>> = net
            .layers
            .iter()
            .map(|l| {
                let lw = weights.get(&l.name).expect("validated");
                (l.name.clone(), compress_kernel4(&lw.w))
            })
            .collect();
        Ok(CycleSimBackend { net, weights, cfg, planes: Arc::new(planes) })
    }

    /// New backend reusing already-compressed weight planes — the
    /// multi-chip cluster shares one compression across all its chips.
    pub fn with_planes(
        net: Arc<NetworkSpec>,
        weights: Arc<ModelWeights>,
        cfg: AccelConfig,
        planes: Arc<BTreeMap<String, Vec<BitMaskKernel>>>,
    ) -> Result<CycleSimBackend> {
        weights.validate_against(&net)?;
        Ok(CycleSimBackend { net, weights, cfg, planes })
    }

    /// The hardware configuration this backend simulates.
    pub fn config(&self) -> &AccelConfig {
        &self.cfg
    }
}

impl SnnBackend for CycleSimBackend {
    fn name(&self) -> &'static str {
        "cyclesim"
    }

    fn caps(&self) -> BackendCaps {
        Self::CAPS
    }

    fn run_frame(&self, image: &Tensor<u8>, opts: &FrameOptions) -> Result<BackendFrame> {
        let mut ctrl = SystemController::new(self.cfg.clone());
        // Per-layer compressed outputs, keyed by name (kept for the CSP
        // concat wiring; the tiny serving geometry makes this cheap).
        let mut outputs: BTreeMap<String, Vec<SpikeMap>> = BTreeMap::new();
        let mut prev: Option<String> = None;
        let mut head: Option<Tensor<i32>> = None;
        let mut layers: BTreeMap<String, LayerObservation> = BTreeMap::new();

        for l in &self.net.layers {
            let lw = self.weights.get(&l.name).expect("validated");
            let planes = self.planes.get(&l.name).expect("compressed at construction");
            // The head accumulates its membrane over in_t steps even
            // though the spec says it emits one averaged output step.
            let mut spec = l.clone();
            if l.kind == ConvKind::Output {
                spec.out_t = l.in_t;
            }
            let (run, input_sparsity) = if l.kind == ConvKind::Encoding {
                // Every encoding step replays the same static frame; only
                // clone when the layer really takes multiple steps.
                let run = if l.in_t == 1 {
                    ctrl.run_layer_prepared(
                        &spec,
                        lw,
                        planes,
                        LayerInput::Pixels(std::slice::from_ref(image)),
                    )
                } else {
                    let frames = vec![image.clone(); l.in_t];
                    ctrl.run_layer_prepared(&spec, lw, planes, LayerInput::Pixels(&frames))
                }
                .with_context(|| format!("simulating layer {}", l.name))?;
                (run, image.sparsity())
            } else {
                let main = l
                    .input_from
                    .clone()
                    .or_else(|| prev.clone())
                    .ok_or_else(|| anyhow!("layer {} has no predecessor", l.name))?;
                let main_steps = outputs
                    .get(&main)
                    .ok_or_else(|| anyhow!("layer {}: missing output of {main}", l.name))?;
                let inputs: Vec<SpikeMap> = match l.concat_with.as_deref() {
                    None => main_steps.clone(),
                    Some(o) => {
                        let os = outputs
                            .get(o)
                            .ok_or_else(|| anyhow!("layer {}: missing output of {o}", l.name))?;
                        main_steps.iter().zip(os).map(|(a, b)| a.concat(b)).collect()
                    }
                };
                let sparsity =
                    inputs.iter().map(|m| m.sparsity()).sum::<f64>() / inputs.len().max(1) as f64;
                let run = ctrl
                    .run_layer_prepared(&spec, lw, planes, LayerInput::Spikes(&inputs))
                    .with_context(|| format!("simulating layer {}", l.name))?;
                (run, sparsity)
            };
            if opts.collect_stats {
                layers.insert(
                    l.name.clone(),
                    LayerObservation {
                        input_sparsity,
                        spikes_out: run.spikes_out,
                        cycles: run.cycles,
                        dense_cycles: run.dense_cycles,
                        core_cycles: run.core_cycles.clone(),
                    },
                );
            }
            if l.kind == ConvKind::Output {
                head = run.head_acc;
            } else {
                outputs.insert(l.name.clone(), run.output);
            }
            prev = Some(l.name.clone());
        }
        let head_acc = head.ok_or_else(|| anyhow!("network has no output layer"))?;
        Ok(BackendFrame { head_acc, layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GoldenBackend;
    use crate::model::topology::{Scale, TimeStepConfig};
    use crate::ref_impl::ForwardOptions;
    use crate::util::Rng;

    fn setup() -> (Arc<NetworkSpec>, Arc<ModelWeights>, Tensor<u8>) {
        let net = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        let mut w = ModelWeights::random(&net, 1.0, 50);
        w.prune_fine_grained(0.8);
        let mut rng = Rng::new(51);
        let n = net.input_c * net.input_h * net.input_w;
        let img = Tensor::from_vec(
            net.input_c,
            net.input_h,
            net.input_w,
            (0..n).map(|_| rng.next_u32() as u8).collect(),
        );
        (Arc::new(net), Arc::new(w), img)
    }

    #[test]
    fn bit_exact_against_golden_with_hardware_tile() {
        let (net, w, img) = setup();
        let cfg = AccelConfig::paper();
        let golden = GoldenBackend::new(
            net.clone(),
            w.clone(),
            ForwardOptions { block_tile: Some((cfg.tile_w, cfg.tile_h)), record_spikes: false },
        )
        .unwrap();
        let sim = CycleSimBackend::new(net, w, cfg).unwrap();
        let opts = FrameOptions { collect_stats: true };
        let a = golden.run_frame(&img, &opts).unwrap();
        let b = sim.run_frame(&img, &opts).unwrap();
        assert_eq!(a.head_acc.data, b.head_acc.data);
        // Spike popcounts agree layer for layer; only the simulator
        // reports cycles.
        for (name, obs) in &b.layers {
            if name != "head" {
                assert_eq!(obs.spikes_out, a.layers[name].spikes_out, "{name}");
            }
            assert!(obs.cycles > 0, "{name}");
            assert!(obs.cycles <= obs.dense_cycles, "{name}");
        }
        assert!(b.total_cycles() > 0);
    }

    #[test]
    fn multicore_frame_is_bit_identical_and_faster() {
        let (net, w, img) = setup();
        let one = CycleSimBackend::new(net.clone(), w.clone(), AccelConfig::paper()).unwrap();
        let four =
            CycleSimBackend::new(net, w, AccelConfig::paper().with_cores(4)).unwrap();
        let opts = FrameOptions { collect_stats: true };
        let a = one.run_frame(&img, &opts).unwrap();
        let b = four.run_frame(&img, &opts).unwrap();
        assert_eq!(a.head_acc.data, b.head_acc.data);
        // Tiny scale: the first layers have ≥ 4 tiles, so the frame
        // makespan must strictly drop; no layer may get slower.
        assert!(b.total_cycles() < a.total_cycles());
        for (name, obs) in &b.layers {
            assert!(obs.cycles <= a.layers[name].cycles, "{name}");
            assert_eq!(obs.core_cycles.len(), 4, "{name}");
            assert_eq!(obs.spikes_out, a.layers[name].spikes_out, "{name}");
        }
    }

    #[test]
    fn stats_off_skips_observations() {
        let (net, w, img) = setup();
        let sim = CycleSimBackend::new(net, w, AccelConfig::paper()).unwrap();
        let frame = sim.run_frame(&img, &FrameOptions::default()).unwrap();
        assert!(frame.layers.is_empty());
        assert!(sim.caps().reports_cycles);
    }
}
