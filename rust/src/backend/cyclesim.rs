//! Cycle-simulator backend: the whole network executed layer by layer on
//! the cycle-level `SystemController`, compressed spike maps threaded
//! between layers (CSP shortcut/concat wiring included). Bit-exact
//! against the golden model run with the hardware block tile, and the
//! only backend that reports cycle counts — per layer and per simulated
//! core (`AccelConfig::num_cores`).
//!
//! The layer walk itself lives in [`crate::exec`]: `run_frame` is a thin
//! [`LayerWalk`] instantiation over [`NopHooks`] (one controller, no
//! routing), the same driver the multi-chip cluster runs with its shard
//! hooks — so the bit-exactness between the two paths is structural, not
//! test-enforced.
//!
//! The per-`(k, c)` bit-mask weight planes are compressed **once** at
//! construction and shared across frames and worker threads behind an
//! `Arc` — the serving path never re-compresses weights per frame.

use super::{BackendCaps, BackendFrame, FrameOptions, SnnBackend};
use crate::config::AccelConfig;
use crate::exec::{LayerWalk, NopHooks};
use crate::model::topology::NetworkSpec;
use crate::model::weights::ModelWeights;
use crate::sparse::{bitmask::compress_kernel4, BitMaskKernel};
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

/// The cycle-level simulator behind the [`SnnBackend`] interface.
pub struct CycleSimBackend {
    net: Arc<NetworkSpec>,
    weights: Arc<ModelWeights>,
    cfg: AccelConfig,
    /// Per-layer compressed weight planes, built once.
    planes: Arc<BTreeMap<String, Vec<BitMaskKernel>>>,
}

impl CycleSimBackend {
    /// Static capabilities (also returned by [`SnnBackend::caps`]) — the
    /// auto-select policy reads these without constructing a backend.
    pub const CAPS: BackendCaps =
        BackendCaps { parallel: true, reports_sparsity: true, reports_cycles: true };

    /// New backend bound to a hardware configuration; validates weights
    /// and compresses every layer's kernel into bit-mask planes.
    pub fn new(
        net: Arc<NetworkSpec>,
        weights: Arc<ModelWeights>,
        cfg: AccelConfig,
    ) -> Result<CycleSimBackend> {
        weights.validate_against(&net)?;
        let planes: BTreeMap<String, Vec<BitMaskKernel>> = net
            .layers
            .iter()
            .map(|l| {
                let lw = weights.get(&l.name).expect("validated");
                (l.name.clone(), compress_kernel4(&lw.w))
            })
            .collect();
        Ok(CycleSimBackend { net, weights, cfg, planes: Arc::new(planes) })
    }

    /// New backend reusing already-compressed weight planes — the
    /// multi-chip cluster shares one compression across all its chips.
    pub fn with_planes(
        net: Arc<NetworkSpec>,
        weights: Arc<ModelWeights>,
        cfg: AccelConfig,
        planes: Arc<BTreeMap<String, Vec<BitMaskKernel>>>,
    ) -> Result<CycleSimBackend> {
        weights.validate_against(&net)?;
        Ok(CycleSimBackend { net, weights, cfg, planes })
    }

    /// The hardware configuration this backend simulates.
    pub fn config(&self) -> &AccelConfig {
        &self.cfg
    }
}

impl SnnBackend for CycleSimBackend {
    fn name(&self) -> &'static str {
        "cyclesim"
    }

    fn caps(&self) -> BackendCaps {
        Self::CAPS
    }

    fn run_frame(&self, image: &Tensor<u8>, opts: &FrameOptions) -> Result<BackendFrame> {
        // The whole dataflow lives in the shared walk; this backend is
        // its trivial instantiation (one controller, nothing routed).
        let mut hooks = NopHooks::new(self.cfg.clone());
        LayerWalk::new(&self.net, &self.weights, &self.planes).run(image, opts, &mut hooks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GoldenBackend;
    use crate::model::topology::{Scale, TimeStepConfig};
    use crate::ref_impl::ForwardOptions;
    use crate::util::Rng;

    fn setup() -> (Arc<NetworkSpec>, Arc<ModelWeights>, Tensor<u8>) {
        let net = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        let mut w = ModelWeights::random(&net, 1.0, 50);
        w.prune_fine_grained(0.8);
        let mut rng = Rng::new(51);
        let n = net.input_c * net.input_h * net.input_w;
        let img = Tensor::from_vec(
            net.input_c,
            net.input_h,
            net.input_w,
            (0..n).map(|_| rng.next_u32() as u8).collect(),
        );
        (Arc::new(net), Arc::new(w), img)
    }

    #[test]
    fn bit_exact_against_golden_with_hardware_tile() {
        let (net, w, img) = setup();
        let cfg = AccelConfig::paper();
        let golden = GoldenBackend::new(
            net.clone(),
            w.clone(),
            ForwardOptions { block_tile: Some((cfg.tile_w, cfg.tile_h)), record_spikes: false },
        )
        .unwrap();
        let sim = CycleSimBackend::new(net, w, cfg).unwrap();
        let opts = FrameOptions { collect_stats: true };
        let a = golden.run_frame(&img, &opts).unwrap();
        let b = sim.run_frame(&img, &opts).unwrap();
        assert_eq!(a.head_acc.data, b.head_acc.data);
        // Spike popcounts agree layer for layer; only the simulator
        // reports cycles.
        for (name, obs) in &b.layers {
            if name != "head" {
                assert_eq!(obs.spikes_out, a.layers[name].spikes_out, "{name}");
            }
            assert!(obs.cycles > 0, "{name}");
            assert!(obs.cycles <= obs.dense_cycles, "{name}");
        }
        assert!(b.total_cycles() > 0);
    }

    #[test]
    fn multicore_frame_is_bit_identical_and_faster() {
        let (net, w, img) = setup();
        let one = CycleSimBackend::new(net.clone(), w.clone(), AccelConfig::paper()).unwrap();
        let four =
            CycleSimBackend::new(net, w, AccelConfig::paper().with_cores(4)).unwrap();
        let opts = FrameOptions { collect_stats: true };
        let a = one.run_frame(&img, &opts).unwrap();
        let b = four.run_frame(&img, &opts).unwrap();
        assert_eq!(a.head_acc.data, b.head_acc.data);
        // Tiny scale: the first layers have ≥ 4 tiles, so the frame
        // makespan must strictly drop; no layer may get slower.
        assert!(b.total_cycles() < a.total_cycles());
        for (name, obs) in &b.layers {
            assert!(obs.cycles <= a.layers[name].cycles, "{name}");
            assert_eq!(obs.core_cycles.len(), 4, "{name}");
            assert_eq!(obs.spikes_out, a.layers[name].spikes_out, "{name}");
        }
    }

    #[test]
    fn stats_off_skips_observations() {
        let (net, w, img) = setup();
        let sim = CycleSimBackend::new(net, w, AccelConfig::paper()).unwrap();
        let frame = sim.run_frame(&img, &FrameOptions::default()).unwrap();
        assert!(frame.layers.is_empty());
        assert!(sim.caps().reports_cycles);
    }
}
