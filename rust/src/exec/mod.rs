//! The **one** cycle-level layer walk behind every execution path.
//!
//! Before this module existed the repo carried two hand-synchronized
//! copies of the frame dataflow — `CycleSimBackend::run_frame` and the
//! cluster's `run_sharded` — each re-implementing the head `out_t`
//! override, the encoding-frame replay, the CSP concat wiring and the
//! compressed spike-plane routing, pinned together only by equivalence
//! tests. [`LayerWalk`] extracts that walk once; per-execution-context
//! behavior (single chip, per-chip layer shard, pipeline-stage handoff)
//! is a [`WalkHooks`] implementation instead of a forked loop, so the
//! bit-exactness between execution paths is now **structural**:
//!
//! ```text
//!                 ┌──────────────── LayerWalk ────────────────┐
//!  image ───────▶ │ for each layer:                           │
//!                 │   on_layer_start(li)                      │
//!                 │   resolve inputs (prev / input_from,      │
//!                 │                   concat_with, replay)    │
//!                 │   route_input(li, RoutedInput)  ──────────┼──▶ interconnect
//!                 │   controller(li).run_layer_prepared(...)  │    transfers,
//!                 │   on_layer_output(li, LayerRun) ──────────┼──▶ chip/cycle
//!                 │   stash spike planes / head accumulator   │    attribution
//!                 └───────────────────────────────────────────┘
//!                                  │
//!                                  ▼
//!                      BackendFrame (head + observations)
//! ```
//!
//! - [`NopHooks`] — a bare [`SystemController`]: exactly the plain
//!   single-chip cycle simulator ([`crate::backend::CycleSimBackend`]).
//! - The cluster's shard hooks (see `crate::cluster`) — pick a per-chip
//!   controller per layer, record interconnect transfers in
//!   `route_input`, attribute busy cycles in `on_layer_output`.
//!
//! The walk is **resumable**: [`WalkState`] carries the inter-layer
//! spike planes, so a caller can execute an arbitrary subset of layers
//! per call ([`LayerWalk::run_layers`]). That is the seam both pipelined
//! executors use to keep several frames resident at different pipeline
//! stages — the modeled-cycle beat loop (`ChipCluster::run_pipelined`)
//! and the wall-clock stage executor
//! (`coordinator::stage_exec::StageExecutor`), which additionally relies
//! on the state being `Send` (stage jobs hop between worker threads) and
//! on the [`StageCompletion`] events it records to audit stage order.

use crate::accel::controller::{LayerInput, LayerRun, SystemController};
use crate::backend::{BackendFrame, FrameOptions, LayerObservation};
use crate::config::AccelConfig;
use crate::model::topology::{ConvKind, ConvSpec, NetworkSpec};
use crate::model::weights::ModelWeights;
use crate::sparse::{BitMaskKernel, SpikeMap};
use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;

/// One layer's resolved stimulus, handed to [`WalkHooks::route_input`]
/// before the layer executes — everything a hook needs to price the data
/// movement that feeds the layer.
pub enum RoutedInput<'i> {
    /// Encoding layer: the multibit pixel frame (replayed across the
    /// layer's `in_t` steps from on-chip caches).
    Pixels {
        /// The static input frame.
        image: &'i Tensor<u8>,
    },
    /// Spike layer (hidden or head): the assembled stimulus plus the
    /// upstream dependencies it was assembled from.
    Spikes {
        /// Possibly-concatenated input maps, one per input time step —
        /// exactly what the controller will consume.
        inputs: &'i [SpikeMap],
        /// Upstream dependencies by producing-layer name with their raw
        /// outputs (main input first, then any `concat_with` source).
        deps: &'i [(&'i str, &'i [SpikeMap])],
    },
}

/// Per-layer callbacks that turn the shared walk into a concrete
/// execution context. Every method except [`Self::controller`] has a
/// no-op default, so the trivial single-chip context implements nothing
/// else.
pub trait WalkHooks {
    /// The controller that executes layer `li` — the only mandatory
    /// hook. A single-chip context always returns the same controller; a
    /// sharded context returns the owning chip's.
    fn controller(&mut self, li: usize) -> &mut SystemController;

    /// A layer is about to be resolved and executed.
    fn on_layer_start(&mut self, _li: usize, _spec: &ConvSpec) -> Result<()> {
        Ok(())
    }

    /// The layer's stimulus is assembled; account any data movement that
    /// brings it to the executing chip (dependency shipping, halo
    /// exchange).
    fn route_input(
        &mut self,
        _li: usize,
        _spec: &ConvSpec,
        _input: &RoutedInput<'_>,
    ) -> Result<()> {
        Ok(())
    }

    /// The layer finished; attribute its cycles/energy and record where
    /// its output now lives.
    fn on_layer_output(&mut self, _li: usize, _spec: &ConvSpec, _run: &LayerRun) -> Result<()> {
        Ok(())
    }
}

/// The trivial hook set: one [`SystemController`], no routing, no
/// attribution — a [`LayerWalk`] over `NopHooks` **is** the plain
/// single-chip cycle simulator, bit for bit and cycle for cycle
/// (property-tested in `tests/exec_walk.rs`).
///
/// Because the controller persists across [`LayerWalk::run`] calls, its
/// scratch arena (PE/LIF state, extracted input tiles) is reused across
/// frames as well as across tiles — the memoized hot path. The
/// cross-frame bit-identity of that reuse is pinned below.
pub struct NopHooks {
    ctrl: SystemController,
}

impl NopHooks {
    /// New single-controller context for a hardware configuration.
    pub fn new(cfg: AccelConfig) -> NopHooks {
        NopHooks { ctrl: SystemController::new(cfg) }
    }
}

impl WalkHooks for NopHooks {
    fn controller(&mut self, _li: usize) -> &mut SystemController {
        &mut self.ctrl
    }
}

/// One stage-completion event recorded on a resumable [`WalkState`]: the
/// wall-clock stage executor tags each `run_layers` call with its stage
/// index so consumers can audit that a frame's stages completed in order
/// even when the jobs hopped between worker threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StageCompletion {
    /// Caller-assigned stage index.
    pub stage: usize,
    /// Total layers executed on this state when the stage completed.
    pub layers_done: usize,
}

/// The walk's inter-layer state: compressed spike planes keyed by
/// producing layer, the implicit-predecessor cursor, the head
/// accumulator, and any collected observations. Keeping it separate from
/// [`LayerWalk`] makes the walk resumable — a caller may execute a few
/// layers, do something else (ship planes to another chip, admit another
/// frame), then continue. The state is `Send`: the wall-clock stage
/// executor parks it between stage jobs that run on different worker
/// threads.
#[derive(Default)]
pub struct WalkState {
    outputs: BTreeMap<String, Vec<SpikeMap>>,
    prev: Option<String>,
    head: Option<Tensor<i32>>,
    layers: BTreeMap<String, LayerObservation>,
    layers_done: usize,
    stage_events: Vec<StageCompletion>,
}

// Compile-time guarantee, not a convention: a resumable walk must be able
// to cross threads for the stage executor to exist.
#[allow(dead_code)]
fn _walk_state_is_send(st: WalkState) -> impl Send {
    st
}

impl WalkState {
    /// Fresh state for one frame.
    pub fn new() -> WalkState {
        WalkState::default()
    }

    /// Whether the output layer has produced the head accumulator (i.e.
    /// the walk reached the end of the network).
    pub fn has_head(&self) -> bool {
        self.head.is_some()
    }

    /// Compressed outputs of a layer, if it ran already.
    pub fn output_of(&self, layer: &str) -> Option<&[SpikeMap]> {
        self.outputs.get(layer).map(|v| v.as_slice())
    }

    /// Total layers executed against this state so far (across all
    /// `run_layers` calls).
    pub fn layers_done(&self) -> usize {
        self.layers_done
    }

    /// Mark the end of one executor stage; pairs each caller-defined
    /// stage with the walk progress it reached.
    pub fn record_stage_completion(&mut self, stage: usize) {
        self.stage_events.push(StageCompletion { stage, layers_done: self.layers_done });
    }

    /// Stage-completion events, in completion order.
    pub fn stage_completions(&self) -> &[StageCompletion] {
        &self.stage_events
    }
}

/// The shared cycle-level layer-walk driver. Borrows the network, the
/// weights and the once-compressed bit-mask planes; owns no mutable
/// state, so one walk can drive many frames (and many hook contexts)
/// concurrently.
pub struct LayerWalk<'a> {
    net: &'a NetworkSpec,
    weights: &'a ModelWeights,
    planes: &'a BTreeMap<String, Vec<BitMaskKernel>>,
}

impl<'a> LayerWalk<'a> {
    /// New walk over a validated network with pre-compressed weight
    /// planes (one `Vec<BitMaskKernel>` per layer, as built by
    /// `compress_kernel4`).
    pub fn new(
        net: &'a NetworkSpec,
        weights: &'a ModelWeights,
        planes: &'a BTreeMap<String, Vec<BitMaskKernel>>,
    ) -> LayerWalk<'a> {
        LayerWalk { net, weights, planes }
    }

    /// Execute the whole network on one frame and assemble the backend
    /// result.
    pub fn run(
        &self,
        image: &Tensor<u8>,
        opts: &FrameOptions,
        hooks: &mut dyn WalkHooks,
    ) -> Result<BackendFrame> {
        let mut st = WalkState::new();
        self.run_layers(&mut st, 0..self.net.layers.len(), image, opts, hooks)?;
        Self::finish(st)
    }

    /// Execute a subset of layers (by index into `net.layers`, in the
    /// given order) against a resumable [`WalkState`] — the pipelined
    /// executor's per-stage entry point. Layers must be executed in
    /// topological (list) order across calls; a layer whose inputs have
    /// not been produced yet is an error.
    pub fn run_layers(
        &self,
        st: &mut WalkState,
        layers: impl IntoIterator<Item = usize>,
        image: &Tensor<u8>,
        opts: &FrameOptions,
        hooks: &mut dyn WalkHooks,
    ) -> Result<()> {
        for li in layers {
            let l = &self.net.layers[li];
            let lw = self.weights.get(&l.name).expect("validated");
            let planes = self.planes.get(&l.name).expect("compressed at construction");
            hooks.on_layer_start(li, l)?;

            // The head accumulates its membrane over in_t steps even
            // though the spec says it emits one averaged output step.
            let mut spec = l.clone();
            if l.kind == ConvKind::Output {
                spec.out_t = l.in_t;
            }

            let (run, input_sparsity) = if l.kind == ConvKind::Encoding {
                hooks.route_input(li, l, &RoutedInput::Pixels { image })?;
                // Every encoding step replays the same static frame; only
                // clone when the layer really takes multiple steps.
                let run = if l.in_t == 1 {
                    hooks.controller(li).run_layer_prepared(
                        &spec,
                        lw,
                        planes,
                        LayerInput::Pixels(std::slice::from_ref(image)),
                    )
                } else {
                    let frames = vec![image.clone(); l.in_t];
                    hooks.controller(li).run_layer_prepared(
                        &spec,
                        lw,
                        planes,
                        LayerInput::Pixels(&frames),
                    )
                }
                .with_context(|| format!("simulating layer {}", l.name))?;
                (run, image.sparsity())
            } else {
                let main = l
                    .input_from
                    .clone()
                    .or_else(|| st.prev.clone())
                    .ok_or_else(|| anyhow!("layer {} has no predecessor", l.name))?;
                let main_steps = st
                    .outputs
                    .get(&main)
                    .ok_or_else(|| anyhow!("layer {}: missing output of {main}", l.name))?;
                let inputs: Vec<SpikeMap> = match l.concat_with.as_deref() {
                    None => main_steps.clone(),
                    Some(o) => {
                        let os = st
                            .outputs
                            .get(o)
                            .ok_or_else(|| anyhow!("layer {}: missing output of {o}", l.name))?;
                        main_steps.iter().zip(os).map(|(a, b)| a.concat(b)).collect()
                    }
                };
                let mut deps: Vec<(&str, &[SpikeMap])> =
                    vec![(main.as_str(), main_steps.as_slice())];
                if let Some(o) = l.concat_with.as_deref() {
                    deps.push((o, st.outputs.get(o).expect("checked above").as_slice()));
                }
                hooks.route_input(li, l, &RoutedInput::Spikes { inputs: &inputs, deps: &deps })?;
                let sparsity =
                    inputs.iter().map(|m| m.sparsity()).sum::<f64>() / inputs.len().max(1) as f64;
                let run = hooks
                    .controller(li)
                    .run_layer_prepared(&spec, lw, planes, LayerInput::Spikes(&inputs))
                    .with_context(|| format!("simulating layer {}", l.name))?;
                (run, sparsity)
            };

            hooks.on_layer_output(li, l, &run)?;
            if opts.collect_stats {
                st.layers.insert(
                    l.name.clone(),
                    LayerObservation {
                        input_sparsity,
                        spikes_out: run.spikes_out,
                        cycles: run.cycles,
                        dense_cycles: run.dense_cycles,
                        core_cycles: run.core_cycles.clone(),
                        patterns_unique: run.patterns_unique,
                        macs_reused: run.macs_reused,
                        rows_unchanged: run.rows_unchanged,
                        cache_hits: run.cache_hits,
                        macs_reused_temporal: run.macs_reused_temporal,
                    },
                );
            }
            if l.kind == ConvKind::Output {
                st.head = run.head_acc;
            } else {
                st.outputs.insert(l.name.clone(), run.output);
            }
            st.prev = Some(l.name.clone());
            st.layers_done += 1;
        }
        Ok(())
    }

    /// Close out a finished walk: the head accumulator plus whatever
    /// observations were collected.
    pub fn finish(st: WalkState) -> Result<BackendFrame> {
        let head_acc = st.head.ok_or_else(|| anyhow!("network has no output layer"))?;
        Ok(BackendFrame { head_acc, layers: st.layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::topology::{Scale, TimeStepConfig};
    use crate::sparse::bitmask::compress_kernel4;
    use crate::util::Rng;

    fn setup() -> (NetworkSpec, ModelWeights, Tensor<u8>) {
        let net = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        let mut w = ModelWeights::random(&net, 1.0, 300);
        w.prune_fine_grained(0.8);
        let mut rng = Rng::new(301);
        let n = net.input_c * net.input_h * net.input_w;
        let img = Tensor::from_vec(
            net.input_c,
            net.input_h,
            net.input_w,
            (0..n).map(|_| rng.next_u32() as u8).collect(),
        );
        (net, w, img)
    }

    fn planes_of(net: &NetworkSpec, w: &ModelWeights) -> BTreeMap<String, Vec<BitMaskKernel>> {
        net.layers
            .iter()
            .map(|l| (l.name.clone(), compress_kernel4(&w.get(&l.name).unwrap().w)))
            .collect()
    }

    #[test]
    fn whole_walk_equals_staged_walk() {
        // Running all layers in one call and layer-by-layer against a
        // resumable state must be identical — the property the pipelined
        // stage executor rests on.
        let (net, w, img) = setup();
        let planes = planes_of(&net, &w);
        let walk = LayerWalk::new(&net, &w, &planes);
        let opts = FrameOptions { collect_stats: true };

        let mut hooks = NopHooks::new(AccelConfig::paper());
        let whole = walk.run(&img, &opts, &mut hooks).unwrap();

        let mut hooks = NopHooks::new(AccelConfig::paper());
        let mut st = WalkState::new();
        for li in 0..net.layers.len() {
            assert!(!st.has_head());
            walk.run_layers(&mut st, [li], &img, &opts, &mut hooks).unwrap();
        }
        assert!(st.has_head());
        let staged = LayerWalk::finish(st).unwrap();
        assert_eq!(whole, staged);
    }

    #[test]
    fn state_tracks_outputs_and_head() {
        let (net, w, img) = setup();
        let planes = planes_of(&net, &w);
        let walk = LayerWalk::new(&net, &w, &planes);
        let mut hooks = NopHooks::new(AccelConfig::paper());
        let mut st = WalkState::new();
        walk.run_layers(&mut st, [0usize], &img, &FrameOptions::default(), &mut hooks).unwrap();
        let first = net.layers[0].name.clone();
        assert!(st.output_of(&first).is_some());
        assert!(st.output_of("head").is_none());
        assert!(!st.has_head());
        // Finishing before the head ran is an error, not a silent zero.
        assert!(LayerWalk::finish(st).is_err());
    }

    #[test]
    fn stage_completions_record_progress() {
        let (net, w, img) = setup();
        let planes = planes_of(&net, &w);
        let walk = LayerWalk::new(&net, &w, &planes);
        let mut hooks = NopHooks::new(AccelConfig::paper());
        let mut st = WalkState::new();
        let opts = FrameOptions::default();
        walk.run_layers(&mut st, [0usize], &img, &opts, &mut hooks).unwrap();
        st.record_stage_completion(0);
        walk.run_layers(&mut st, 1..net.layers.len(), &img, &opts, &mut hooks).unwrap();
        st.record_stage_completion(1);
        assert_eq!(st.layers_done(), net.layers.len());
        let ev = st.stage_completions();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0], StageCompletion { stage: 0, layers_done: 1 });
        assert_eq!(ev[1], StageCompletion { stage: 1, layers_done: net.layers.len() });
    }

    #[test]
    fn reused_controller_scratch_is_bit_identical_across_frames() {
        // One hook set (one controller, one scratch arena) serving many
        // frames must produce exactly what a fresh controller per frame
        // produces — the property the memoized tile extraction rests on.
        let (net, w, img) = setup();
        let planes = planes_of(&net, &w);
        let walk = LayerWalk::new(&net, &w, &planes);
        let opts = FrameOptions { collect_stats: true };

        // Second frame with a different activity pattern.
        let mut rng = Rng::new(777);
        let n = net.input_c * net.input_h * net.input_w;
        let img2 = Tensor::from_vec(
            net.input_c,
            net.input_h,
            net.input_w,
            (0..n).map(|_| rng.next_u32() as u8).collect(),
        );

        let mut reused = NopHooks::new(AccelConfig::paper());
        let got: Vec<BackendFrame> = [&img, &img2, &img]
            .iter()
            .map(|im| walk.run(im, &opts, &mut reused).unwrap())
            .collect();
        let want: Vec<BackendFrame> = [&img, &img2, &img]
            .iter()
            .map(|im| {
                let mut fresh = NopHooks::new(AccelConfig::paper());
                walk.run(im, &opts, &mut fresh).unwrap()
            })
            .collect();
        assert_eq!(got, want);
        // Same image through the warm scratch is reproducible too.
        assert_eq!(got[0], got[2]);
    }

    #[test]
    fn out_of_order_layer_is_an_error() {
        let (net, w, img) = setup();
        let planes = planes_of(&net, &w);
        let walk = LayerWalk::new(&net, &w, &planes);
        let mut hooks = NopHooks::new(AccelConfig::paper());
        let mut st = WalkState::new();
        // Layer 1 consumes layer 0's spikes, which don't exist yet.
        let err =
            walk.run_layers(&mut st, [1usize], &img, &FrameOptions::default(), &mut hooks);
        assert!(err.is_err());
    }
}
