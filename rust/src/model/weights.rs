//! Quantized weight containers + the model-slimming operations of §II
//! (fine-grained pruning, 8-bit quantization), and the `SNNW` artifact
//! format shared with `python/compile/binfmt.py`.

use crate::model::topology::NetworkSpec;
use crate::tensor::{Kernel4, QuantParams};
use crate::util::io::*;
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// One layer's quantized weights (BN already folded in by the build path).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerWeights {
    /// 8-bit weights `(k, c, kh, kw)`.
    pub w: Kernel4<i8>,
    /// Per-output-channel bias in the 16-bit accumulator domain.
    pub bias: Vec<i32>,
    /// Quantization parameters (scale + integer threshold).
    pub qp: QuantParams,
}

impl LayerWeights {
    /// Weight density (fraction nonzero) — the y-axis of Fig 3.
    pub fn density(&self) -> f64 {
        1.0 - self.w.sparsity()
    }

    /// Number of nonzero weights.
    pub fn nnz(&self) -> usize {
        self.w.count_nonzero()
    }
}

/// All layers of a model, keyed by layer name.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ModelWeights {
    layers: BTreeMap<String, LayerWeights>,
}

const MAGIC: &[u8; 4] = b"SNNW";
const VERSION: u32 = 1;

impl ModelWeights {
    /// Insert a layer.
    pub fn insert(&mut self, name: &str, lw: LayerWeights) {
        self.layers.insert(name.to_string(), lw);
    }

    /// Layer lookup.
    pub fn get(&self, name: &str) -> Option<&LayerWeights> {
        self.layers.get(name)
    }

    /// Mutable lookup.
    pub fn get_mut(&mut self, name: &str) -> Option<&mut LayerWeights> {
        self.layers.get_mut(name)
    }

    /// Iterate layers in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &LayerWeights)> {
        self.layers.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// Whether empty.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Total nonzero / total weights over the whole model.
    pub fn density(&self) -> f64 {
        let total: usize = self.layers.values().map(|l| l.w.data.len()).sum();
        let nnz: usize = self.layers.values().map(|l| l.nnz()).sum();
        if total == 0 {
            0.0
        } else {
            nnz as f64 / total as f64
        }
    }

    /// Generate random weights for a network spec — used by tests, the
    /// simulator's stimulus generator, and benches that don't need trained
    /// weights. `density` < 1.0 pre-sparsifies 3×3 kernels (1×1 kernels
    /// are kept dense, like the paper's pruning policy).
    pub fn random(net: &NetworkSpec, density: f64, seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let mut mw = ModelWeights::default();
        for l in &net.layers {
            let qp = QuantParams::from_weight_absmax(1.0);
            let mut w = Kernel4::zeros(l.c_out, l.c_in, l.k, l.k);
            for v in w.data.iter_mut() {
                let keep = l.k == 1 || rng.chance(density);
                if keep {
                    // Avoid exact zeros so density is exact for kept slots.
                    let mag = rng.range_i64(1, 127);
                    *v = (mag * if rng.chance(0.5) { 1 } else { -1 }) as i8;
                }
            }
            let bias = (0..l.c_out).map(|_| rng.range_i64(-8, 8) as i32).collect();
            mw.insert(&l.name, LayerWeights { w, bias, qp });
        }
        mw
    }

    /// Fine-grained magnitude pruning (§II-C, [26]): zero the smallest
    /// `rate` fraction of weights in every **3×3** kernel tensor; 1×1
    /// kernels are kept intact, per the paper's policy.
    pub fn prune_fine_grained(&mut self, rate: f64) {
        for lw in self.layers.values_mut() {
            if lw.w.kh == 1 && lw.w.kw == 1 {
                continue;
            }
            let mut mags: Vec<i16> = lw.w.data.iter().map(|&w| (w as i16).abs()).collect();
            mags.sort_unstable();
            let cut = ((mags.len() as f64 * rate) as usize).min(mags.len().saturating_sub(1));
            let threshold = mags[cut];
            for v in lw.w.data.iter_mut() {
                if (*v as i16).abs() < threshold.max(1) {
                    *v = 0;
                }
            }
        }
    }

    /// Serialize to the `SNNW` artifact format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        write_u32(&mut w, VERSION)?;
        write_u32(&mut w, self.layers.len() as u32)?;
        for (name, lw) in &self.layers {
            write_string(&mut w, name)?;
            write_u32(&mut w, lw.w.k as u32)?;
            write_u32(&mut w, lw.w.c as u32)?;
            write_u32(&mut w, lw.w.kh as u32)?;
            write_u32(&mut w, lw.w.kw as u32)?;
            write_f32(&mut w, lw.qp.scale)?;
            write_i32(&mut w, lw.qp.vth_q)?;
            for &b in &lw.bias {
                write_i32(&mut w, b)?;
            }
            let bytes: Vec<u8> = lw.w.data.iter().map(|&v| v as u8).collect();
            w.write_all(&bytes)?;
        }
        Ok(())
    }

    /// Load from the `SNNW` artifact format.
    pub fn load(path: &Path) -> Result<ModelWeights> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening weights {}", path.display()))?;
        let mut r = BufReader::new(f);
        Self::read(&mut r)
    }

    /// Load from any reader.
    pub fn read(r: &mut impl Read) -> Result<ModelWeights> {
        expect_magic(r, MAGIC)?;
        let version = read_u32(r)?;
        if version != VERSION {
            bail!("unsupported SNNW version {version}");
        }
        let n = read_u32(r)? as usize;
        let mut mw = ModelWeights::default();
        for _ in 0..n {
            let name = read_string(r)?;
            let k = read_u32(r)? as usize;
            let c = read_u32(r)? as usize;
            let kh = read_u32(r)? as usize;
            let kw = read_u32(r)? as usize;
            let scale = read_f32(r)?;
            let vth_q = read_i32(r)?;
            let mut bias = Vec::with_capacity(k);
            for _ in 0..k {
                bias.push(read_i32(r)?);
            }
            let raw = read_bytes(r, k * c * kh * kw)?;
            let data: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
            mw.insert(
                &name,
                LayerWeights {
                    w: Kernel4::from_vec(k, c, kh, kw, data),
                    bias,
                    qp: QuantParams { scale, vth_q },
                },
            );
        }
        Ok(mw)
    }

    /// Validate that the weights cover a network spec exactly.
    pub fn validate_against(&self, net: &NetworkSpec) -> Result<()> {
        for l in &net.layers {
            let Some(lw) = self.get(&l.name) else {
                bail!("weights missing layer {:?}", l.name);
            };
            if lw.w.k != l.c_out || lw.w.c != l.c_in || lw.w.kh != l.k || lw.w.kw != l.k {
                bail!(
                    "layer {:?}: weight shape ({},{},{},{}) != spec ({},{},{},{})",
                    l.name, lw.w.k, lw.w.c, lw.w.kh, lw.w.kw, l.c_out, l.c_in, l.k, l.k
                );
            }
            if lw.bias.len() != l.c_out {
                bail!("layer {:?}: bias length mismatch", l.name);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::topology::{Scale, TimeStepConfig};
    use crate::util::propcheck::run_prop;

    fn tiny_net() -> NetworkSpec {
        NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER)
    }

    #[test]
    fn random_weights_match_spec() {
        let net = tiny_net();
        let mw = ModelWeights::random(&net, 1.0, 1);
        mw.validate_against(&net).unwrap();
        assert_eq!(mw.len(), net.layers.len());
    }

    #[test]
    fn pruning_hits_target_rate_on_3x3() {
        let net = tiny_net();
        let mut mw = ModelWeights::random(&net, 1.0, 2);
        mw.prune_fine_grained(0.8);
        let enc = mw.get("enc").unwrap();
        let density = enc.density();
        assert!(density < 0.35, "density={density}");
        // 1×1 layers untouched (paper policy).
        let short = mw.get("b1.short").unwrap();
        assert!(short.density() > 0.99, "1x1 density={}", short.density());
    }

    #[test]
    fn save_load_roundtrip() {
        let net = tiny_net();
        let mut mw = ModelWeights::random(&net, 0.5, 3);
        mw.prune_fine_grained(0.8);
        let dir = std::env::temp_dir().join("scsnn_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        mw.save(&p).unwrap();
        let back = ModelWeights::load(&p).unwrap();
        assert_eq!(mw, back);
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("scsnn_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(ModelWeights::load(&p).is_err());
    }

    #[test]
    fn validate_catches_shape_mismatch() {
        let net = tiny_net();
        let mut mw = ModelWeights::random(&net, 1.0, 4);
        let lw = mw.get_mut("enc").unwrap();
        lw.bias.pop();
        assert!(mw.validate_against(&net).is_err());
    }

    #[test]
    fn prop_pruning_monotone() {
        run_prop("weights/pruning-monotone", |g| {
            let net = tiny_net();
            let seed = g.rng().next_u64();
            let mut a = ModelWeights::random(&net, 1.0, seed);
            let mut b = a.clone();
            a.prune_fine_grained(0.5);
            b.prune_fine_grained(0.9);
            assert!(b.density() <= a.density() + 1e-9);
        });
    }

    #[test]
    fn paper_pruning_reduces_70pct_of_weights() {
        // §II-C: pruning 80% of 3×3 kernels removes ~70% of all weights
        // (1×1 kernels survive). Check the same arithmetic holds on our
        // geometry at full scale.
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        let mut mw = ModelWeights::random(&net, 1.0, 5);
        mw.prune_fine_grained(0.8);
        let density = mw.density();
        let removed = 1.0 - density;
        assert!((0.60..0.85).contains(&removed), "removed={removed}");
    }
}
