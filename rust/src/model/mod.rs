//! Network description, LIF neuron dynamics, weight containers, and the
//! paper's mIoUT metric (§II).

pub mod lif;
pub mod miout;
pub mod topology;
pub mod weights;

pub use lif::{LifState, LifParams};
pub use miout::MioutAccumulator;
pub use topology::{ConvKind, ConvSpec, NetworkSpec, Scale, TimeStepConfig};
pub use weights::{LayerWeights, ModelWeights};
