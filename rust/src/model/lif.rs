//! Leaky integrate-and-fire neuron dynamics (§I, §II-A).
//!
//! The paper uses a discrete-time LIF with a delta synaptic kernel,
//! threshold 0.5 and leak 0.25 — constants chosen so the integer datapath
//! needs only a comparator and an arithmetic shift. Update rule (hard
//! reset, as in STBP/tdBN training):
//!
//! ```text
//! u[t] = leak(u[t-1] · (1 − s[t-1])) + I[t]
//! s[t] = u[t] ≥ vth
//! ```
//!
//! All arithmetic happens in the quantized integer domain: `I[t]` is the
//! 16-bit conv accumulator, `u` is stored back at 8 bits (saturating) —
//! matching the chip's "8-bit FXP @ Vmem, 16-bit FXP @ Acc" datapath.

use crate::tensor::{sat_i8, QuantParams};

/// Static LIF parameters in the integer domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LifParams {
    /// Integer firing threshold (`round(0.5 / scale)`).
    pub vth_q: i32,
}

impl LifParams {
    /// From per-layer quantization parameters.
    pub fn from_quant(qp: &QuantParams) -> Self {
        LifParams { vth_q: qp.vth_q }
    }
}

/// Per-neuron membrane state across time steps.
#[derive(Clone, Debug, Default)]
pub struct LifState {
    /// 8-bit membrane potential per neuron (saturating storage).
    pub vmem: Vec<i8>,
    /// Last spike per neuron (drives the hard reset).
    pub fired: Vec<bool>,
}

impl LifState {
    /// Fresh state for `n` neurons (potential 0, nothing fired).
    pub fn new(n: usize) -> Self {
        LifState { vmem: vec![0; n], fired: vec![false; n] }
    }

    /// Advance one time step for every neuron given its integrated conv
    /// input `acc[i]` (16-bit accumulator domain, passed as i32), writing
    /// output spikes into `spikes`. Returns the number of fired neurons.
    pub fn step(&mut self, p: LifParams, acc: &[i32], spikes: &mut [u8]) -> usize {
        assert_eq!(acc.len(), self.vmem.len());
        assert_eq!(spikes.len(), self.vmem.len());
        let mut fired_count = 0;
        for i in 0..self.vmem.len() {
            let residual = if self.fired[i] { 0 } else { self.vmem[i] as i32 };
            let u = QuantParams::leak(residual) + acc[i];
            let s = u >= p.vth_q;
            self.vmem[i] = sat_i8(u);
            self.fired[i] = s;
            spikes[i] = u8::from(s);
            fired_count += usize::from(s);
        }
        fired_count
    }

    /// Reset all neurons (between frames).
    pub fn reset(&mut self) {
        self.vmem.iter_mut().for_each(|v| *v = 0);
        self.fired.iter_mut().for_each(|f| *f = false);
    }
}

/// Pure single-neuron reference used by tests and the hardware LIF unit's
/// verification: returns `(new_vmem, spike)`.
pub fn lif_step_scalar(vmem: i8, fired_prev: bool, acc: i32, vth_q: i32) -> (i8, bool) {
    let residual = if fired_prev { 0 } else { vmem as i32 };
    let u = QuantParams::leak(residual) + acc;
    (sat_i8(u), u >= vth_q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::run_prop;

    const P: LifParams = LifParams { vth_q: 32 };

    #[test]
    fn integrates_below_threshold() {
        let mut s = LifState::new(1);
        let mut out = [0u8];
        // 20 < 32: no fire, potential retained.
        assert_eq!(s.step(P, &[20], &mut out), 0);
        assert_eq!(out[0], 0);
        assert_eq!(s.vmem[0], 20);
        // leak(20) + 20 = 5 + 20 = 25 < 32: still silent.
        s.step(P, &[20], &mut out);
        assert_eq!(s.vmem[0], 25);
        // leak(25) + 28 = 6 + 28 = 34 ≥ 32: fire.
        assert_eq!(s.step(P, &[28], &mut out), 1);
        assert_eq!(out[0], 1);
    }

    #[test]
    fn hard_reset_after_fire() {
        let mut s = LifState::new(1);
        let mut out = [0u8];
        s.step(P, &[100], &mut out);
        assert_eq!(out[0], 1);
        // Residual is dropped: next potential is just the new input.
        s.step(P, &[10], &mut out);
        assert_eq!(s.vmem[0], 10);
        assert_eq!(out[0], 0);
    }

    #[test]
    fn vmem_saturates_to_8bit() {
        let mut s = LifState::new(1);
        let mut out = [0u8];
        s.step(LifParams { vth_q: 1000 }, &[500], &mut out);
        assert_eq!(s.vmem[0], 127);
        assert_eq!(out[0], 0);
        s.step(LifParams { vth_q: 1000 }, &[-5000], &mut out);
        assert_eq!(s.vmem[0], -128);
    }

    #[test]
    fn negative_potential_decays_symmetrically() {
        let mut s = LifState::new(1);
        let mut out = [0u8];
        s.step(P, &[-40], &mut out);
        assert_eq!(s.vmem[0], -40);
        s.step(P, &[0], &mut out);
        assert_eq!(s.vmem[0], -10); // -40 >> 2 toward zero
    }

    #[test]
    fn scalar_matches_vector() {
        run_prop("lif/scalar-vs-vector", |g| {
            let n = g.usize(1, 64);
            let vth = g.i64(1, 96) as i32;
            let mut st = LifState::new(n);
            let mut spikes = vec![0u8; n];
            for _ in 0..4 {
                let acc: Vec<i32> = g.vec(n, |g| g.i64(-300, 300) as i32);
                let prev: Vec<(i8, bool)> =
                    st.vmem.iter().zip(&st.fired).map(|(&v, &f)| (v, f)).collect();
                st.step(LifParams { vth_q: vth }, &acc, &mut spikes);
                for i in 0..n {
                    let (v, s) = lif_step_scalar(prev[i].0, prev[i].1, acc[i], vth);
                    assert_eq!(st.vmem[i], v);
                    assert_eq!(spikes[i] == 1, s);
                }
            }
        });
    }

    #[test]
    fn reset_clears_state() {
        let mut s = LifState::new(3);
        let mut out = [0u8; 3];
        s.step(P, &[100, 5, -7], &mut out);
        s.reset();
        assert!(s.vmem.iter().all(|&v| v == 0));
        assert!(s.fired.iter().all(|&f| !f));
    }
}
