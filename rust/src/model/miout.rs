//! The paper's mIoUT metric — *mean Intersection over Union across
//! Time-steps* (§II-D, Eq. 1, Fig 4).
//!
//! For each channel: accumulate per-neuron firing counts over the `T` time
//! steps. The **intersection** is the set of neurons that fired at *every*
//! step (count == T); the **union** is the set of neurons that fired at
//! least once. `mIoUT = mean_c (|intersection_c| / |union_c|)` — 1.0 means
//! the feature maps are identical across time steps, so the layer's input
//! can drop to a single time step at little cost (the basis for the mixed
//! time-step selection of Fig 5 / Fig 15).

use crate::sparse::SpikeMap;
use crate::tensor::Tensor;

/// Streaming accumulator over time steps for one layer's input feature map.
#[derive(Clone, Debug)]
pub struct MioutAccumulator {
    c: usize,
    hw: usize,
    t_seen: usize,
    /// Per-neuron firing count.
    counts: Vec<u16>,
}

impl MioutAccumulator {
    /// For a `(c, h, w)` spike map.
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        MioutAccumulator { c, hw: h * w, t_seen: 0, counts: vec![0; c * h * w] }
    }

    /// Accumulate one time step's spike map.
    pub fn push(&mut self, spikes: &Tensor<u8>) {
        assert_eq!(spikes.c * spikes.h * spikes.w, self.counts.len(), "shape mismatch");
        for (cnt, &s) in self.counts.iter_mut().zip(&spikes.data) {
            *cnt += u16::from(s != 0);
        }
        self.t_seen += 1;
    }

    /// Accumulate one time step from a **compressed** spike map — only
    /// fired neurons are visited (O(popcount), the golden model's native
    /// recording format).
    pub fn push_map(&mut self, spikes: &SpikeMap) {
        assert_eq!(spikes.len(), self.counts.len(), "shape mismatch");
        for ch in 0..spikes.c {
            let base = ch * self.hw;
            for (y, x) in spikes.plane(ch).iter_set() {
                self.counts[base + y * spikes.w + x] += 1;
            }
        }
        self.t_seen += 1;
    }

    /// Total time steps accumulated so far.
    pub fn time_steps(&self) -> usize {
        self.t_seen
    }

    /// Compute mIoUT per Eq. 1. Channels whose union is empty (completely
    /// silent) carry no information about temporal similarity and are
    /// excluded from the mean; returns `None` if every channel is silent
    /// or fewer than 2 time steps were accumulated.
    pub fn miout(&self) -> Option<f64> {
        if self.t_seen < 2 {
            return None;
        }
        let t = self.t_seen as u16;
        let mut sum = 0.0;
        let mut active_channels = 0usize;
        for ch in 0..self.c {
            let slice = &self.counts[ch * self.hw..(ch + 1) * self.hw];
            let union = slice.iter().filter(|&&n| n > 0).count();
            if union == 0 {
                continue;
            }
            let inter = slice.iter().filter(|&&n| n == t).count();
            sum += inter as f64 / union as f64;
            active_channels += 1;
        }
        (active_channels > 0).then(|| sum / active_channels as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::run_prop;

    /// The worked example of Fig 4: over 3 time steps, 4 neurons fire at
    /// every step and 2 more fire at least once → mIoUT = 4/6 ≈ 0.67.
    #[test]
    fn fig4_example() {
        let mut acc = MioutAccumulator::new(1, 3, 3);
        // Neurons 0..4 fire every step; neuron 4 fires at t0 only,
        // neuron 5 at t2 only; the rest stay silent.
        let t0 = Tensor::from_vec(1, 3, 3, vec![1, 1, 1, 1, 1, 0, 0, 0, 0]);
        let t1 = Tensor::from_vec(1, 3, 3, vec![1, 1, 1, 1, 0, 0, 0, 0, 0]);
        let t2 = Tensor::from_vec(1, 3, 3, vec![1, 1, 1, 1, 0, 1, 0, 0, 0]);
        acc.push(&t0);
        acc.push(&t1);
        acc.push(&t2);
        let m = acc.miout().unwrap();
        assert!((m - 4.0 / 6.0).abs() < 1e-12, "m={m}");
    }

    #[test]
    fn identical_maps_give_one() {
        let mut acc = MioutAccumulator::new(2, 2, 2);
        let t = Tensor::from_vec(2, 2, 2, vec![1, 0, 1, 0, 0, 1, 0, 0]);
        for _ in 0..3 {
            acc.push(&t);
        }
        assert_eq!(acc.miout(), Some(1.0));
    }

    #[test]
    fn disjoint_maps_give_zero() {
        let mut acc = MioutAccumulator::new(1, 1, 2);
        acc.push(&Tensor::from_vec(1, 1, 2, vec![1, 0]));
        acc.push(&Tensor::from_vec(1, 1, 2, vec![0, 1]));
        assert_eq!(acc.miout(), Some(0.0));
    }

    #[test]
    fn silent_channels_excluded() {
        let mut acc = MioutAccumulator::new(2, 1, 2);
        // Channel 0 identical across steps; channel 1 silent.
        let t = Tensor::from_vec(2, 1, 2, vec![1, 1, 0, 0]);
        acc.push(&t);
        acc.push(&t);
        assert_eq!(acc.miout(), Some(1.0));
    }

    #[test]
    fn insufficient_steps_is_none() {
        let mut acc = MioutAccumulator::new(1, 1, 1);
        assert_eq!(acc.miout(), None);
        acc.push(&Tensor::from_vec(1, 1, 1, vec![1]));
        assert_eq!(acc.miout(), None);
    }

    #[test]
    fn push_map_matches_dense_push() {
        run_prop("miout/map-vs-dense", |g| {
            let c = g.usize(1, 3);
            let h = g.usize(1, 5);
            let w = g.usize(1, 5);
            let mut a = MioutAccumulator::new(c, h, w);
            let mut b = MioutAccumulator::new(c, h, w);
            for _ in 0..3 {
                let t = Tensor::from_vec(c, h, w, g.spikes(c * h * w, 0.4));
                a.push(&t);
                b.push_map(&SpikeMap::from_dense(&t));
            }
            assert_eq!(a.miout(), b.miout());
        });
    }

    #[test]
    fn prop_miout_in_unit_interval() {
        run_prop("miout/unit-interval", |g| {
            let c = g.usize(1, 4);
            let h = g.usize(1, 6);
            let w = g.usize(1, 6);
            let t = g.usize(2, 5);
            let mut acc = MioutAccumulator::new(c, h, w);
            for _ in 0..t {
                let data = g.spikes(c * h * w, 0.4);
                acc.push(&Tensor::from_vec(c, h, w, data));
            }
            if let Some(m) = acc.miout() {
                assert!((0.0..=1.0).contains(&m), "m={m}");
            }
        });
    }
}
