//! The paper's object-detection network (Fig 1 / Fig 2) as data.
//!
//! The network is a flat list of convolution layers — exactly how the
//! accelerator sees it (every CSP basic block lowers to four convs: two
//! stacked 3×3, a 1×1 shortcut, and a 1×1 aggregation after channel
//! concat). Downsampling is a 2×2 max pool (OR gate in hardware) fused
//! after a layer.
//!
//! Two scales are provided (see DESIGN.md §8): `Full` is the paper's
//! 1024×576 / ~3.3M-parameter geometry used analytically by the hardware
//! experiments; `Tiny` is a width/4, 320×192 variant that is actually
//! trained and executed end to end.

/// Layer role, which fixes its time-step and reset semantics (§II-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvKind {
    /// Multibit RGB input, bit-serial (B=8), "fires once": conv + tdBN +
    /// LIF with a single time step.
    Encoding,
    /// Spike-in / spike-out convolution + tdBN + LIF.
    Spike,
    /// Detection head: accumulates membrane with no reset and averages
    /// over time steps; produces multibit output.
    Output,
}

/// One convolution layer as the hardware sees it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvSpec {
    /// Unique layer name, e.g. `b2.stack1`.
    pub name: String,
    /// Role.
    pub kind: ConvKind,
    /// Input channels.
    pub c_in: usize,
    /// Output channels.
    pub c_out: usize,
    /// Kernel size (square; paper supports 1..=3).
    pub k: usize,
    /// Input time steps.
    pub in_t: usize,
    /// Output time steps.
    pub out_t: usize,
    /// 2×2 max pool fused after this layer.
    pub maxpool_after: bool,
    /// Input feature width at this layer.
    pub in_w: usize,
    /// Input feature height at this layer.
    pub in_h: usize,
    /// For CSP blocks: name of the layer whose output is concatenated
    /// *before* this layer's input (the aggregation conv consumes
    /// `concat(stack2, shortcut)`). Empty for sequential layers.
    pub concat_with: Option<String>,
    /// Which earlier layer feeds this one (None = previous in list).
    /// Used by the shortcut conv inside a CSP block, which reads the
    /// block input rather than the stacked path.
    pub input_from: Option<String>,
}

impl ConvSpec {
    /// Output spatial width (stride-1 convs, same padding).
    pub fn out_w(&self) -> usize {
        if self.maxpool_after {
            self.in_w / 2
        } else {
            self.in_w
        }
    }

    /// Output spatial height.
    pub fn out_h(&self) -> usize {
        if self.maxpool_after {
            self.in_h / 2
        } else {
            self.in_h
        }
    }

    /// Number of weights.
    pub fn num_weights(&self) -> usize {
        self.c_out * self.c_in * self.k * self.k
    }

    /// Dense MACs for one full forward (all time steps, all bit planes).
    /// Conv is computed `in_t` times (the mixed-time-step trick computes
    /// it once when `in_t == 1` regardless of `out_t`), and the encoding
    /// layer is bit-serial over 8 planes.
    pub fn dense_macs(&self) -> u64 {
        let planes = if self.kind == ConvKind::Encoding { 8 } else { 1 };
        (self.num_weights() as u64)
            * (self.in_w as u64)
            * (self.in_h as u64)
            * (self.in_t as u64)
            * planes as u64
    }

    /// Dense operation count (1 MAC = 2 ops, matching Table III's footnote).
    pub fn dense_ops(&self) -> u64 {
        2 * self.dense_macs()
    }
}

/// Model scale (DESIGN.md §8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Paper geometry: 1024×576, ~3.3M parameters.
    Full,
    /// Trained/executed geometry: 320×192, width ÷ 4.
    Tiny,
}

impl Scale {
    /// Input resolution `(w, h)`.
    pub fn input_size(self) -> (usize, usize) {
        match self {
            Scale::Full => (1024, 576),
            Scale::Tiny => (320, 192),
        }
    }

    /// Channel width divider.
    pub fn width_div(self) -> usize {
        match self {
            Scale::Full => 1,
            Scale::Tiny => 4,
        }
    }

    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "full" => Some(Scale::Full),
            "tiny" => Some(Scale::Tiny),
            _ => None,
        }
    }
}

/// Mixed-time-step configuration (Fig 15): how many leading layers run
/// with a single input time step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeStepConfig {
    /// Every layer uses `t` time steps (the unmixed baseline).
    Uniform(usize),
    /// `C1`: only the encoding conv takes one time step.
    C1(usize),
    /// `C2`: the first two convs take one time step (the paper's choice,
    /// `(1, t)` mixed time steps).
    C2(usize),
    /// `C2BX`: the first two convs *and* the first `x` basic blocks take
    /// one time step.
    C2B(usize, usize),
}

impl TimeStepConfig {
    /// The paper's shipped configuration: mixed (1, 3).
    pub const PAPER: TimeStepConfig = TimeStepConfig::C2(3);

    /// Steady-state time steps `t`.
    pub fn t(&self) -> usize {
        match *self {
            TimeStepConfig::Uniform(t)
            | TimeStepConfig::C1(t)
            | TimeStepConfig::C2(t)
            | TimeStepConfig::C2B(_, t) => t,
        }
    }

    /// Number of *leading basic blocks* running at one time step.
    fn one_t_blocks(&self) -> usize {
        match *self {
            TimeStepConfig::C2B(x, _) => x,
            _ => 0,
        }
    }

    /// Whether the encoding conv's LIF repeats to `t` outputs immediately
    /// (C1) or the single-step region extends further (C2/C2B).
    fn one_t_convs(&self) -> usize {
        match *self {
            TimeStepConfig::Uniform(_) => 0,
            TimeStepConfig::C1(_) => 1,
            TimeStepConfig::C2(_) | TimeStepConfig::C2B(..) => 2,
        }
    }

    /// Short label matching Fig 15's x-axis.
    pub fn label(&self) -> String {
        match *self {
            TimeStepConfig::Uniform(t) => format!("T{t}"),
            TimeStepConfig::C1(_) => "C1".into(),
            TimeStepConfig::C2(_) => "C2".into(),
            TimeStepConfig::C2B(x, _) => format!("C2B{x}"),
        }
    }
}

/// A complete network: ordered conv layers plus input geometry.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkSpec {
    /// Human-readable name.
    pub name: String,
    /// Input width.
    pub input_w: usize,
    /// Input height.
    pub input_h: usize,
    /// Input channels (RGB = 3).
    pub input_c: usize,
    /// Layers in execution order.
    pub layers: Vec<ConvSpec>,
    /// Detection head geometry: number of anchors.
    pub num_anchors: usize,
    /// Number of object classes.
    pub num_classes: usize,
}

impl NetworkSpec {
    /// Build the paper's network (Fig 1) at a given scale and time-step
    /// configuration.
    ///
    /// Structure: Encoding(3→32) ⌄pool, Conv(32→64) ⌄pool, then four CSP
    /// basic blocks (64→128 ⌄, 128→256 ⌄, 256→512 ⌄, 512→512) and a 1×1
    /// output conv to `anchors × (5 + classes)`. Channel counts divide by
    /// `scale.width_div()`.
    pub fn paper(scale: Scale, ts: TimeStepConfig) -> NetworkSpec {
        let (iw, ih) = scale.input_size();
        let d = scale.width_div();
        let t = ts.t();
        let num_anchors = 5;
        let num_classes = 3;

        let mut b = Builder::new(iw, ih, t, ts);
        // Encoding conv (in_t is always 1: fires once from the image).
        b.conv("enc", ConvKind::Encoding, 3, 32 / d, 3, true);
        // Second conv ("conv block" in Fig 1).
        b.conv("conv1", ConvKind::Spike, 32 / d, 64 / d, 3, true);
        // CSP basic blocks.
        b.basic_block("b1", 64 / d, 128 / d, 64 / d, true);
        b.basic_block("b2", 128 / d, 256 / d, 128 / d, true);
        b.basic_block("b3", 256 / d, 512 / d, 256 / d, true);
        b.basic_block("b4", 512 / d, 512 / d, 192 / d, false);
        // Output conv (1×1 head).
        let head = num_anchors * (5 + num_classes);
        b.conv("head", ConvKind::Output, 512 / d, head, 1, false);

        NetworkSpec {
            name: format!("ivs3cls-{:?}-{}", scale, ts.label()),
            input_w: iw,
            input_h: ih,
            input_c: 3,
            layers: b.layers,
            num_anchors,
            num_classes,
        }
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.num_weights() + l.c_out).sum()
    }

    /// Total dense operations for one frame (Fig 15's op-count axis).
    pub fn dense_ops(&self) -> u64 {
        self.layers.iter().map(|l| l.dense_ops()).sum()
    }

    /// Layer lookup by name.
    pub fn layer(&self, name: &str) -> Option<&ConvSpec> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Names of all layers, in order.
    pub fn layer_names(&self) -> Vec<&str> {
        self.layers.iter().map(|l| l.name.as_str()).collect()
    }

    /// Detection grid size `(gw, gh)` — the output of the last layer.
    pub fn grid(&self) -> (usize, usize) {
        let last = self.layers.last().expect("network has layers");
        (last.out_w(), last.out_h())
    }
}

/// Incremental builder tracking spatial size and time-step region.
struct Builder {
    layers: Vec<ConvSpec>,
    w: usize,
    h: usize,
    t: usize,
    ts: TimeStepConfig,
    convs_done: usize,
    blocks_done: usize,
}

impl Builder {
    fn new(w: usize, h: usize, t: usize, ts: TimeStepConfig) -> Self {
        Builder { layers: Vec::new(), w, h, t, ts, convs_done: 0, blocks_done: 0 }
    }

    /// in/out time steps for the next sequential layer given the mixed
    /// configuration: layers inside the single-step region run 1→1, the
    /// layer at the boundary runs 1→t, and everything after runs t→t.
    /// The output head always emits a single (averaged) step.
    fn times(&self, kind: ConvKind) -> (usize, usize) {
        let one_convs = self.ts.one_t_convs();
        let one_blocks = self.ts.one_t_blocks();
        // Index of this conv in the "leading convs" count (enc=0, conv1=1).
        let conv_idx = self.convs_done;
        let in_one = if conv_idx < one_convs {
            true
        } else {
            // Inside the single-step block region? Blocks count after the
            // two leading convs.
            one_convs == 2 && self.blocks_done < one_blocks
        };
        // The *next* position still single-step? The boundary layer emits t.
        let next_in_one = match kind {
            ConvKind::Output => false,
            _ => {
                let nc = conv_idx + 1;
                if nc < one_convs {
                    true
                } else {
                    one_convs == 2 && self.next_blocks_done() < one_blocks
                }
            }
        };
        let in_t = if in_one { 1 } else { self.t };
        let out_t = match kind {
            ConvKind::Output => 1,
            _ => {
                if next_in_one {
                    1
                } else {
                    self.t
                }
            }
        };
        // Uniform config: encoding still fires once per step from the same
        // image — modeled as in_t = t (recomputed each step).
        (in_t, out_t)
    }

    fn push(&mut self, mut spec: ConvSpec) {
        spec.in_w = self.w;
        spec.in_h = self.h;
        if spec.maxpool_after {
            self.w /= 2;
            self.h /= 2;
        }
        self.layers.push(spec);
    }

    fn conv(
        &mut self,
        name: &str,
        kind: ConvKind,
        c_in: usize,
        c_out: usize,
        k: usize,
        pool: bool,
    ) {
        let (in_t, out_t) = self.times(kind);
        self.push(ConvSpec {
            name: name.into(),
            kind,
            c_in,
            c_out,
            k,
            in_t,
            out_t,
            maxpool_after: pool,
            in_w: 0,
            in_h: 0,
            concat_with: None,
            input_from: None,
        });
        self.convs_done += 1;
    }

    fn next_blocks_done(&self) -> usize {
        self.blocks_done
    }

    /// CSP basic block (Fig 2b): two stacked 3×3 convs (width `c_s`), a
    /// 1×1 shortcut at `c_s/2` channels reading the block input, and a 1×1
    /// aggregation conv over the concatenation.
    fn basic_block(&mut self, name: &str, c_in: usize, c_out: usize, c_s: usize, pool: bool) {
        let c_sh = c_s / 2;
        let (in_t, out_t_region) = {
            // All convs inside a block share the block's time region;
            // the aggregation layer decides the output time step.
            let (i, _) = self.times(ConvKind::Spike);
            (i, ())
        };
        let _ = out_t_region;
        let block_input = self
            .layers
            .last()
            .map(|l| l.name.clone())
            .expect("basic block needs a predecessor");
        let mk = |nm: &str| format!("{name}.{nm}");

        // Stacked path.
        self.push(ConvSpec {
            name: mk("stack1"),
            kind: ConvKind::Spike,
            c_in,
            c_out: c_s,
            k: 3,
            in_t,
            out_t: in_t,
            maxpool_after: false,
            in_w: 0,
            in_h: 0,
            concat_with: None,
            input_from: None,
        });
        self.push(ConvSpec {
            name: mk("stack2"),
            kind: ConvKind::Spike,
            c_in: c_s,
            c_out: c_s,
            k: 3,
            in_t,
            out_t: in_t,
            maxpool_after: false,
            in_w: 0,
            in_h: 0,
            concat_with: None,
            input_from: None,
        });
        // Shortcut path (reads the block input).
        self.push(ConvSpec {
            name: mk("short"),
            kind: ConvKind::Spike,
            c_in,
            c_out: c_sh,
            k: 1,
            in_t,
            out_t: in_t,
            maxpool_after: false,
            in_w: 0,
            in_h: 0,
            concat_with: None,
            input_from: Some(block_input),
        });
        // Aggregation over concat(stack2, short). Its out_t follows the
        // time-step region boundary.
        self.blocks_done += 1;
        let (_, out_t) = self.times(ConvKind::Spike);
        self.convs_done += 4;
        self.push(ConvSpec {
            name: mk("agg"),
            kind: ConvKind::Spike,
            c_in: c_s + c_sh,
            c_out,
            k: 1,
            in_t,
            out_t,
            maxpool_after: pool,
            in_w: 0,
            in_h: 0,
            concat_with: Some(mk("short")),
            input_from: Some(mk("stack2")),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_scale_geometry() {
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::PAPER);
        assert_eq!(net.input_w, 1024);
        assert_eq!(net.input_h, 576);
        // 2 convs + 4 blocks × 4 convs + head = 19 layers.
        assert_eq!(net.layers.len(), 19);
        // Final grid is exactly one 32×18 hardware tile.
        assert_eq!(net.grid(), (32, 18));
        // Parameter count near the paper's 3.17M.
        let p = net.num_params();
        assert!((2_500_000..4_500_000).contains(&p), "params={p}");
    }

    #[test]
    fn tiny_scale_geometry() {
        let net = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        assert_eq!(net.grid(), (10, 6));
        let p = net.num_params();
        assert!(p < 400_000, "params={p}");
    }

    #[test]
    fn paper_time_steps_c2() {
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::C2(3));
        let enc = net.layer("enc").unwrap();
        let conv1 = net.layer("conv1").unwrap();
        let b1s1 = net.layer("b1.stack1").unwrap();
        let head = net.layer("head").unwrap();
        assert_eq!((enc.in_t, enc.out_t), (1, 1));
        assert_eq!((conv1.in_t, conv1.out_t), (1, 3));
        assert_eq!((b1s1.in_t, b1s1.out_t), (3, 3));
        assert_eq!((head.in_t, head.out_t), (3, 1));
    }

    #[test]
    fn c1_time_steps() {
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::C1(3));
        let enc = net.layer("enc").unwrap();
        let conv1 = net.layer("conv1").unwrap();
        assert_eq!((enc.in_t, enc.out_t), (1, 3));
        assert_eq!((conv1.in_t, conv1.out_t), (3, 3));
    }

    #[test]
    fn c2b1_extends_single_step_region() {
        let net = NetworkSpec::paper(Scale::Full, TimeStepConfig::C2B(1, 3));
        let b1agg = net.layer("b1.agg").unwrap();
        let b2s1 = net.layer("b2.stack1").unwrap();
        assert_eq!((b1agg.in_t, b1agg.out_t), (1, 3));
        assert_eq!((b2s1.in_t, b2s1.out_t), (3, 3));
        // Inner layers of b1 are single-step.
        let b1s2 = net.layer("b1.stack2").unwrap();
        assert_eq!((b1s2.in_t, b1s2.out_t), (1, 1));
    }

    #[test]
    fn mixed_time_steps_reduce_ops() {
        // Fig 15 / §II-D: C2 reduces ops vs the uniform-T baseline, and
        // deeper cuts reduce further.
        let base = NetworkSpec::paper(Scale::Full, TimeStepConfig::Uniform(3)).dense_ops();
        let c1 = NetworkSpec::paper(Scale::Full, TimeStepConfig::C1(3)).dense_ops();
        let c2 = NetworkSpec::paper(Scale::Full, TimeStepConfig::C2(3)).dense_ops();
        let c2b2 = NetworkSpec::paper(Scale::Full, TimeStepConfig::C2B(2, 3)).dense_ops();
        assert!(c1 < base && c2 < c1 && c2b2 < c2, "{base} {c1} {c2} {c2b2}");
        // §II-D: (1,3) mixed time steps ≈ 17% reduction vs original.
        let reduction = 1.0 - c2 as f64 / base as f64;
        assert!((0.05..0.60).contains(&reduction), "reduction={reduction}");
    }

    #[test]
    fn concat_and_shortcut_wiring() {
        let net = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        let agg = net.layer("b1.agg").unwrap();
        assert_eq!(agg.input_from.as_deref(), Some("b1.stack2"));
        assert_eq!(agg.concat_with.as_deref(), Some("b1.short"));
        assert_eq!(agg.c_in, net.layer("b1.stack2").unwrap().c_out + net.layer("b1.short").unwrap().c_out);
        let short = net.layer("b1.short").unwrap();
        assert_eq!(short.input_from.as_deref(), Some("conv1"));
    }

    #[test]
    fn head_channels_match_yolo() {
        let net = NetworkSpec::paper(Scale::Tiny, TimeStepConfig::PAPER);
        let head = net.layer("head").unwrap();
        assert_eq!(head.c_out, 5 * (5 + 3));
        assert_eq!(head.k, 1);
    }
}
