//! Minimal, offline, API-compatible subset of the `anyhow` error crate.
//!
//! The build environment has no registry access, so the pieces of `anyhow`
//! this project actually uses are re-implemented here at the scale needed:
//!
//! - [`Error`]: a context-carrying error value (`Display` shows the
//!   outermost context, `{:#}` the full chain, `Debug` an anyhow-style
//!   multi-line report);
//! - [`Result<T>`]: alias with `Error` as the default error type;
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what allows the blanket
//! `impl From<E: std::error::Error> for Error` to coexist with the
//! reflexive `From<Error> for Error`.

use std::fmt;

/// A context-carrying error value.
///
/// Internally a chain of messages, outermost context first (index 0),
/// innermost cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-separated (anyhow style).
            let mut first = true;
            for msg in &self.chain {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in &self.chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Err::<(), _>(io_err()).context("opening weights").unwrap_err();
        assert_eq!(e.to_string(), "opening weights");
        assert_eq!(format!("{e:#}"), "opening weights: file missing");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("inner")
            .context("outer")
            .unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("file missing"));
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: i32) -> Result<()> {
            ensure!(n < 10, "n too large: {n}");
            if n < 0 {
                bail!("negative {}", n);
            }
            Ok(())
        }
        assert!(fails(3).is_ok());
        assert_eq!(fails(11).unwrap_err().to_string(), "n too large: 11");
        assert_eq!(fails(-2).unwrap_err().to_string(), "negative -2");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing value").unwrap_err().to_string(), "missing value");
        assert_eq!(Some(5u32).context("unused").unwrap(), 5);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
