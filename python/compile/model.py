"""Layer-2 JAX model: the paper's SNN object-detection network (§II).

Three faces of the same network:

1. **Float training model** — STBP surrogate-gradient LIF [21] with
   threshold-dependent batch norm (tdBN) [22], CSP basic blocks, mixed
   time steps, YOLOv2 head. Used by ``train.py``.
2. **Quantized integer inference model** — BN folded into 8-bit weights,
   integer LIF (shift leak, saturating vmem) built from the Layer-1
   Pallas kernels. **Bit-exact** with the rust golden model
   (`rust/src/ref_impl/snn.rs`, whole-image conv mode); this is the graph
   ``aot.py`` lowers to HLO text for the rust runtime.
3. **ANN / QNN / BNN comparison variants** (Table II) — same topology,
   ReLU / fake-quant / sign activations, no time dimension.

The layer list mirrors `rust/src/model/topology.rs` exactly (names,
shapes, time steps, CSP wiring); `tests/test_model.py` pins the geometry.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .binfmt import QuantLayer
from .kernels.gated_conv import gated_conv2d
from .kernels.lif import lif_chain_pallas
from .kernels.ref import maxpool2x2_or, sat_i16

VTH = 0.5  # LIF threshold (§II-A)
LEAK = 0.25  # LIF leak (×0.25 = >>2)
NUM_ANCHORS = 5
NUM_CLASSES = 3
HEAD_CH = NUM_ANCHORS * (5 + NUM_CLASSES)
ANCHORS = ((0.6, 1.2), (1.2, 1.0), (2.2, 1.6), (3.5, 2.4), (5.5, 3.5))


# --------------------------------------------------------------------------
# Topology (mirror of rust/src/model/topology.rs)
# --------------------------------------------------------------------------


@dataclass
class LayerSpec:
    """One conv layer as the hardware sees it."""

    name: str
    kind: str  # "encoding" | "spike" | "output"
    c_in: int
    c_out: int
    k: int
    in_t: int
    out_t: int
    maxpool_after: bool
    in_w: int = 0
    in_h: int = 0
    concat_with: str | None = None
    input_from: str | None = None


@dataclass
class NetworkSpec:
    """The full network."""

    name: str
    input_w: int
    input_h: int
    layers: list[LayerSpec] = field(default_factory=list)

    def layer(self, name: str) -> LayerSpec:
        return next(l for l in self.layers if l.name == name)

    def grid(self) -> tuple[int, int]:
        last = self.layers[-1]
        w = last.in_w // 2 if last.maxpool_after else last.in_w
        h = last.in_h // 2 if last.maxpool_after else last.in_h
        return w, h


def build_network(scale: str = "tiny", t: int = 3, ts_mode: str = "C2", ts_blocks: int = 0) -> NetworkSpec:
    """Build the paper network. ``ts_mode`` ∈ {"uniform","C1","C2","C2B"}
    selects the mixed-time-step configuration (Fig 15); ``ts_blocks`` is
    the X of C2BX."""
    iw, ih = (1024, 576) if scale == "full" else (320, 192)
    d = 1 if scale == "full" else 4
    one_convs = {"uniform": 0, "C1": 1, "C2": 2, "C2B": 2}[ts_mode]
    one_blocks = ts_blocks if ts_mode == "C2B" else 0

    net = NetworkSpec(name=f"ivs3cls-{scale}-{ts_mode}{ts_blocks or ''}", input_w=iw, input_h=ih)
    state = {"w": iw, "h": ih, "convs": 0, "blocks": 0}

    def in_one() -> bool:
        if state["convs"] < one_convs:
            return True
        return one_convs == 2 and state["blocks"] < one_blocks

    def next_one(kind: str) -> bool:
        if kind == "output":
            return False
        nc = state["convs"] + 1
        if nc < one_convs:
            return True
        return one_convs == 2 and state["blocks"] < one_blocks

    def push(spec: LayerSpec) -> None:
        spec.in_w, spec.in_h = state["w"], state["h"]
        if spec.maxpool_after:
            state["w"] //= 2
            state["h"] //= 2
        net.layers.append(spec)

    def conv(name, kind, c_in, c_out, k, pool):
        it = 1 if in_one() else t
        ot = 1 if kind == "output" else (1 if next_one(kind) else t)
        if kind == "output":
            ot = 1
        push(LayerSpec(name, kind, c_in, c_out, k, it, ot, pool))
        state["convs"] += 1

    def basic_block(name, c_in, c_out, c_s, pool):
        c_sh = c_s // 2
        it = 1 if in_one() else t
        block_input = net.layers[-1].name
        push(LayerSpec(f"{name}.stack1", "spike", c_in, c_s, 3, it, it, False))
        push(LayerSpec(f"{name}.stack2", "spike", c_s, c_s, 3, it, it, False))
        push(
            LayerSpec(
                f"{name}.short", "spike", c_in, c_sh, 1, it, it, False, input_from=block_input
            )
        )
        state["blocks"] += 1
        ot = 1 if next_one("spike") else t
        state["convs"] += 4
        push(
            LayerSpec(
                f"{name}.agg",
                "spike",
                c_s + c_sh,
                c_out,
                1,
                it,
                ot,
                pool,
                concat_with=f"{name}.short",
                input_from=f"{name}.stack2",
            )
        )

    conv("enc", "encoding", 3, 32 // d, 3, True)
    conv("conv1", "spike", 32 // d, 64 // d, 3, True)
    basic_block("b1", 64 // d, 128 // d, 64 // d, True)
    basic_block("b2", 128 // d, 256 // d, 128 // d, True)
    basic_block("b3", 256 // d, 512 // d, 256 // d, True)
    basic_block("b4", 512 // d, 512 // d, 192 // d, False)
    conv("head", "output", 512 // d, HEAD_CH, 1, False)
    return net


# --------------------------------------------------------------------------
# Float training model (STBP + tdBN)
# --------------------------------------------------------------------------


@jax.custom_vjp
def spike_fn(u: jnp.ndarray) -> jnp.ndarray:
    """Heaviside spike with STBP rectangular surrogate gradient."""
    return (u >= VTH).astype(u.dtype)


def _spike_fwd(u):
    return spike_fn(u), u


def _spike_bwd(u, g):
    # Rectangular window of width 1 centred on the threshold [21].
    surr = (jnp.abs(u - VTH) < 0.5).astype(u.dtype)
    return (g * surr,)


spike_fn.defvjp(_spike_fwd, _spike_bwd)


def init_params(net: NetworkSpec, seed: int) -> dict:
    """He-style init for conv weights + tdBN scale/shift (per channel)."""
    rng = np.random.default_rng(seed)
    params = {}
    for l in net.layers:
        fan_in = l.c_in * l.k * l.k
        w = rng.normal(0, np.sqrt(2.0 / fan_in), (l.c_out, l.c_in, l.k, l.k))
        p = {"w": jnp.asarray(w, jnp.float32)}
        if l.kind == "output":
            # Objectness logits start at −3 (σ ≈ 0.05) so the detector
            # begins from "nothing anywhere" instead of spending its first
            # hundred steps suppressing 300 cells — standard RetinaNet-style
            # prior initialization, big win at small step budgets.
            b = np.zeros(l.c_out, np.float32)
            per = 5 + NUM_CLASSES
            b[4::per] = -3.0
            p["b"] = jnp.asarray(b)
        else:
            # tdBN: γ initialized to Vth per [22] so pre-activations sit at
            # threshold scale.
            p["gamma"] = jnp.full((l.c_out,), VTH, jnp.float32)
            p["beta"] = jnp.zeros((l.c_out,), jnp.float32)
        params[l.name] = p
    return params


def init_bn_stats(net: NetworkSpec) -> dict:
    """Running mean/var for export-time BN folding."""
    return {
        l.name: {"mean": jnp.zeros((l.c_out,)), "var": jnp.ones((l.c_out,))}
        for l in net.layers
        if l.kind != "output"
    }


def _conv_f32(x, w):
    """Float same-size conv with replicate padding (B, C, H, W)."""
    ph, pw = w.shape[2] // 2, w.shape[3] // 2
    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)), mode="edge")
    return lax.conv_general_dilated(
        xp, w, (1, 1), "VALID", dimension_numbers=("NCHW", "OIHW", "NCHW")
    )


def _maxpool_f32(x):
    b, c, h, w = x.shape
    return x[:, :, : h // 2 * 2, : w // 2 * 2].reshape(b, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


def _tdbn(x_t: jnp.ndarray, gamma, beta, stats, momentum, train: bool):
    """tdBN over the (T, B, H, W) axes per channel. ``x_t``: (T,B,C,H,W)."""
    if train:
        mean = x_t.mean(axis=(0, 1, 3, 4))
        var = x_t.var(axis=(0, 1, 3, 4))
        new_stats = {
            "mean": stats["mean"] * (1 - momentum) + mean * momentum,
            "var": stats["var"] * (1 - momentum) + var * momentum,
        }
    else:
        mean, var = stats["mean"], stats["var"]
        new_stats = stats
    inv = 1.0 / jnp.sqrt(var + 1e-5)
    y = (x_t - mean[:, None, None]) * inv[:, None, None] * gamma[:, None, None] + beta[
        :, None, None
    ]
    return y, new_stats


def _lif_float(accs: jnp.ndarray, out_t: int) -> jnp.ndarray:
    """Float LIF over (T,B,C,H,W) currents → spikes (out_t,B,C,H,W)."""

    def step(carry, acc):
        vmem, prev_s = carry
        u = LEAK * vmem * (1.0 - prev_s) + acc
        s = spike_fn(u)
        return (u, s), s

    if accs.shape[0] < out_t:
        accs = jnp.concatenate([accs] + [accs[-1:]] * (out_t - accs.shape[0]), axis=0)
    zero = jnp.zeros(accs.shape[1:], accs.dtype)
    _, spikes = lax.scan(step, (zero, zero), accs)
    return spikes


def snn_forward_float(
    params: dict, bn_stats: dict, net: NetworkSpec, images: jnp.ndarray, *, train: bool, momentum: float = 0.1
):
    """Float SNN forward. ``images``: (B, 3, H, W) in [0, 1].

    Returns (head (B, HEAD_CH, gh, gw), new_bn_stats, aux spike rates).
    """
    outputs: dict[str, jnp.ndarray] = {}  # name -> (T,B,C,H,W) spikes
    new_stats = {}
    rates = {}
    prev = None
    head = None
    for l in net.layers:
        p = params[l.name]
        if l.kind == "encoding":
            x_t = images[None]  # (1,B,3,H,W)
        else:
            src = outputs[l.input_from or prev]
            if l.concat_with is not None:
                x_t = jnp.concatenate([src, outputs[l.concat_with]], axis=2)
            else:
                x_t = src
        # Conv per input step (vmapped over T).
        accs = jax.vmap(lambda xt: _conv_f32(xt, p["w"]))(x_t)
        if l.kind == "output":
            head = accs.mean(axis=0) + p["b"][:, None, None]
            break
        accs, new_stats[l.name] = _tdbn(
            accs, p["gamma"], p["beta"], bn_stats[l.name], momentum, train
        )
        spikes = _lif_float(accs, l.out_t)
        if l.maxpool_after:
            spikes = jax.vmap(_maxpool_f32)(spikes)
        outputs[l.name] = spikes
        rates[l.name] = spikes.mean()
        prev = l.name
        # Free maps no longer needed (memory hygiene for big batches).
    return head, new_stats, rates


# --------------------------------------------------------------------------
# ANN / QNN / BNN variants (Table II)
# --------------------------------------------------------------------------


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


_ste_round.defvjp(lambda x: (jnp.round(x), None), lambda _, g: (g,))


@jax.custom_vjp
def _ste_sign(x):
    return jnp.sign(x) + (x == 0).astype(x.dtype)


_ste_sign.defvjp(
    lambda x: (jnp.sign(x) + (x == 0).astype(x.dtype), x),
    lambda x, g: (g * (jnp.abs(x) <= 1).astype(x.dtype),),
)


def variant_forward(params, bn_stats, net, images, *, variant: str, act_bits: int = 4, train: bool):
    """ANN ("ann"), quantized-activation ("qnn"), or binary ("bnn") forward
    on the same topology, no time dimension."""
    outputs = {}
    new_stats = {}
    prev = None
    head = None
    for l in net.layers:
        p = params[l.name]
        x = images if l.kind == "encoding" else outputs[l.input_from or prev]
        if l.kind != "encoding" and l.concat_with is not None:
            x = jnp.concatenate([x, outputs[l.concat_with]], axis=1)
        w = p["w"]
        if variant == "bnn" and l.kind != "output":
            w = _ste_sign(w) * jnp.mean(jnp.abs(w))
        acc = _conv_f32(x, w)
        if l.kind == "output":
            head = acc + p["b"][:, None, None]
            break
        acc_t, new_stats[l.name] = _tdbn(
            acc[None], p["gamma"], p["beta"], bn_stats[l.name], 0.1, train
        )
        y = jnp.maximum(acc_t[0], 0.0)
        if variant == "qnn":
            # Fake-quant activations to `act_bits` in [0, 1] (FXP-n).
            levels = 2**act_bits - 1
            y = _ste_round(jnp.clip(y, 0, 1) * levels) / levels
        elif variant == "bnn":
            y = _ste_sign(y - 0.5) * 0.5 + 0.5  # binary {0,1}
        if l.maxpool_after:
            y = _maxpool_f32(y)
        outputs[l.name] = y
        prev = l.name
    return head, new_stats


# --------------------------------------------------------------------------
# BN folding + quantization (→ the rust/AOT integer model)
# --------------------------------------------------------------------------


def fold_and_quantize(params: dict, bn_stats: dict, net: NetworkSpec) -> dict[str, QuantLayer]:
    """Fold tdBN into the weights and quantize to the chip's 8-bit format.

    Mirrors `QuantParams::from_weight_absmax` exactly: scale =
    max(absmax/127, 0.5/96); vth_q = round(0.5/scale). The encoding layer
    additionally folds the /255 input normalization into its weights.
    """
    out = {}
    for l in net.layers:
        p = params[l.name]
        w = np.asarray(p["w"], np.float64)
        if l.kind == "output":
            w_fold, b_fold = w, np.asarray(p["b"], np.float64)
        else:
            st = bn_stats[l.name]
            inv = 1.0 / np.sqrt(np.asarray(st["var"], np.float64) + 1e-5)
            g = np.asarray(p["gamma"], np.float64) * inv
            w_fold = w * g[:, None, None, None]
            b_fold = np.asarray(p["beta"], np.float64) - np.asarray(st["mean"], np.float64) * g
        if l.kind == "encoding":
            w_fold = w_fold / 255.0
        absmax = np.abs(w_fold).max()
        # Scale floor = threshold-domain constraint. Spike layers must store
        # near-threshold residuals in the 8-bit vmem → vth_q ≤ 96. The
        # encoding layer carries no residual (it fires once, §II-A), so its
        # threshold only needs to fit the 16-bit accumulator; the looser
        # floor keeps its /255-folded weights from rounding to zero.
        vth_cap = 8000.0 if l.kind == "encoding" else 96.0
        scale = max(absmax / 127.0, 1e-8, VTH / vth_cap)
        w_q = np.clip(np.round(w_fold / scale), -128, 127).astype(np.int8)
        b_q = np.clip(np.round(b_fold / scale), -(2**15), 2**15 - 1).astype(np.int32)
        vth_q = int(round(VTH / scale))
        out[l.name] = QuantLayer(w=w_q, bias=b_q, scale=float(scale), vth_q=vth_q)
    return out


def prune_fine_grained(qlayers: dict[str, QuantLayer], rate: float) -> dict[str, QuantLayer]:
    """Fine-grained magnitude pruning (§II-C): zero the smallest ``rate``
    fraction of each 3×3 layer's weights; 1×1 layers kept intact. Mirrors
    rust `ModelWeights::prune_fine_grained`."""
    out = {}
    for name, lw in qlayers.items():
        w = lw.w.copy()
        if w.shape[2] * w.shape[3] > 1:
            mags = np.sort(np.abs(w.astype(np.int16)).ravel())
            cut = min(int(len(mags) * rate), len(mags) - 1)
            thr = max(mags[cut], 1)
            w[np.abs(w.astype(np.int16)) < thr] = 0
        out[name] = QuantLayer(w=w, bias=lw.bias.copy(), scale=lw.scale, vth_q=lw.vth_q)
    return out


# --------------------------------------------------------------------------
# Quantized integer inference (the AOT graph; calls the Pallas kernels)
# --------------------------------------------------------------------------


def snn_forward_quant(
    qlayers: dict[str, QuantLayer],
    net: NetworkSpec,
    image_u8: jnp.ndarray,
    *,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Integer forward of one frame. ``image_u8``: (3, H, W) uint8.

    Returns the head accumulator (HEAD_CH, gh, gw) int32 — bit-exact with
    rust `SnnForward::run(..).head_acc` in whole-image mode.

    ``use_pallas`` selects the Layer-1 Pallas kernels (the architecture
    contract; pytest pins them against the jnp oracle) vs the pure
    `lax.conv` oracle graph. Both are bit-identical; the oracle graph is
    what ships as the *runtime* HLO artifact because the interpret-mode
    Pallas lowering (per-grid-step while loops) compiles pathologically
    slowly on the rust client's xla_extension 0.5.1 (see aot.py).
    """
    from .kernels.ref import conv2d_int, lif_chain

    conv = (
        (lambda s, w, b, k: gated_conv2d(s, w, b, kh=k, kw=k))
        if use_pallas
        else (lambda s, w, b, k: conv2d_int(s, w, b))
    )
    lif = lif_chain_pallas if use_pallas else lif_chain
    x = image_u8.astype(jnp.int32)
    outputs: dict[str, jnp.ndarray] = {}
    prev = None
    for l in net.layers:
        lw = qlayers[l.name]
        w = jnp.asarray(lw.w, jnp.int32)
        b = jnp.asarray(lw.bias, jnp.int32)
        if l.kind == "encoding":
            steps = [x] * l.in_t
        else:
            src = outputs[l.input_from or prev]
            if l.concat_with is not None:
                other = outputs[l.concat_with]
                steps = [jnp.concatenate([a, o], axis=0) for a, o in zip(src, other)]
            else:
                steps = list(src)
        # Conv per executed input step — the Layer-1 kernel.
        accs = [conv(s, w, b, l.k) for s in steps]
        if l.kind == "output":
            total = accs[0]
            for a in accs[1:]:
                total = total + a
            return total
        # Mixed time steps: replay the last computed acc (§II-A).
        accs_t = jnp.stack([accs[min(t, len(accs) - 1)] for t in range(l.out_t)])
        spikes = lif(accs_t, lw.vth_q)
        if l.maxpool_after:
            spikes = jax.vmap(maxpool2x2_or)(spikes)
        outputs[l.name] = [spikes[t] for t in range(l.out_t)]
        prev = l.name
    raise AssertionError("network has no head layer")


def head_to_float(head_acc: np.ndarray, qlayers: dict[str, QuantLayer], in_t: int) -> np.ndarray:
    """Dequantize the head accumulator: real = acc × scale / T."""
    return np.asarray(head_acc, np.float64) * qlayers["head"].scale / in_t


# Re-export for callers that only need the saturation helper.
__all__ = [
    "ANCHORS",
    "HEAD_CH",
    "NUM_ANCHORS",
    "NUM_CLASSES",
    "LayerSpec",
    "NetworkSpec",
    "build_network",
    "init_params",
    "init_bn_stats",
    "snn_forward_float",
    "variant_forward",
    "fold_and_quantize",
    "prune_fine_grained",
    "snn_forward_quant",
    "head_to_float",
    "sat_i16",
    "spike_fn",
]
