"""Binary artifact formats shared with the rust request path.

Kept byte-compatible with `rust/src/model/weights.rs` (``SNNW``) and
`rust/src/detect/dataset.rs` (``SNND``). All integers little-endian.

SNNW v1::

    b"SNNW" u32=1 u32=n_layers
    per layer (sorted by name, as rust's BTreeMap iterates):
        u32 len + utf8 name
        u32 k, u32 c, u32 kh, u32 kw
        f32 scale, i32 vth_q
        k × i32 bias
        k*c*kh*kw × i8 weights (row-major k,c,kh,kw)

SNND v1::

    b"SNND" u32=1 u32=n_images
    per image:
        u32 w, u32 h
        3*h*w × u8 pixels (channel-major: R plane, G plane, B plane)
        u32 n_boxes, per box: u32 class_id, f32 cx, cy, w, h (normalized)
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np


@dataclass
class QuantLayer:
    """One layer's quantized weights (mirror of rust `LayerWeights`)."""

    w: np.ndarray  # int8 (k, c, kh, kw)
    bias: np.ndarray  # int32 (k,)
    scale: float
    vth_q: int


def _w_str(f, s: str) -> None:
    b = s.encode("utf-8")
    f.write(struct.pack("<I", len(b)))
    f.write(b)


def write_snnw(path: str, layers: dict[str, QuantLayer]) -> None:
    """Write a SNNW weights file (layers serialized in sorted-name order)."""
    with open(path, "wb") as f:
        f.write(b"SNNW")
        f.write(struct.pack("<II", 1, len(layers)))
        for name in sorted(layers):
            lw = layers[name]
            k, c, kh, kw = lw.w.shape
            _w_str(f, name)
            f.write(struct.pack("<IIII", k, c, kh, kw))
            f.write(struct.pack("<fi", float(lw.scale), int(lw.vth_q)))
            f.write(np.asarray(lw.bias, dtype="<i4").tobytes())
            f.write(np.asarray(lw.w, dtype=np.int8).tobytes())


def read_snnw(path: str) -> dict[str, QuantLayer]:
    """Read a SNNW weights file."""
    out: dict[str, QuantLayer] = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"SNNW", "bad magic"
        version, n = struct.unpack("<II", f.read(8))
        assert version == 1, version
        for _ in range(n):
            (slen,) = struct.unpack("<I", f.read(4))
            name = f.read(slen).decode("utf-8")
            k, c, kh, kw = struct.unpack("<IIII", f.read(16))
            scale, vth_q = struct.unpack("<fi", f.read(8))
            bias = np.frombuffer(f.read(4 * k), dtype="<i4").copy()
            w = (
                np.frombuffer(f.read(k * c * kh * kw), dtype=np.int8)
                .reshape(k, c, kh, kw)
                .copy()
            )
            out[name] = QuantLayer(w=w, bias=bias, scale=scale, vth_q=vth_q)
    return out


def write_snnd(path: str, images: list[np.ndarray], boxes: list[np.ndarray]) -> None:
    """Write a SNND dataset.

    ``images[i]`` is uint8 (3, h, w); ``boxes[i]`` is float32 (n, 5) rows of
    ``(class_id, cx, cy, w, h)``.
    """
    assert len(images) == len(boxes)
    with open(path, "wb") as f:
        f.write(b"SNND")
        f.write(struct.pack("<II", 1, len(images)))
        for img, bxs in zip(images, boxes):
            assert img.dtype == np.uint8 and img.ndim == 3 and img.shape[0] == 3
            _, h, w = img.shape
            f.write(struct.pack("<II", w, h))
            f.write(img.tobytes())
            f.write(struct.pack("<I", len(bxs)))
            for row in bxs:
                f.write(struct.pack("<Iffff", int(row[0]), *map(float, row[1:5])))


def read_snnd(path: str) -> tuple[list[np.ndarray], list[np.ndarray]]:
    """Read a SNND dataset → (images, boxes)."""
    images, boxes = [], []
    with open(path, "rb") as f:
        assert f.read(4) == b"SNND", "bad magic"
        version, n = struct.unpack("<II", f.read(8))
        assert version == 1, version
        for _ in range(n):
            w, h = struct.unpack("<II", f.read(8))
            img = (
                np.frombuffer(f.read(3 * h * w), dtype=np.uint8)
                .reshape(3, h, w)
                .copy()
            )
            (nb,) = struct.unpack("<I", f.read(4))
            rows = np.zeros((nb, 5), np.float32)
            for i in range(nb):
                cid, cx, cy, bw, bh = struct.unpack("<Iffff", f.read(20))
                rows[i] = (cid, cx, cy, bw, bh)
            images.append(img)
            boxes.append(rows)
    return images, boxes
