"""Regenerate the HLO artifacts from an existing ``weights_tiny.bin``
without retraining (used when only the export path changed)::

    cd python && python -m compile.export_hlo --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from .aot import to_hlo_text
from .binfmt import read_snnd, read_snnw
from .model import build_network, snn_forward_quant


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    net = build_network("tiny", t=3, ts_mode="C2")
    q = read_snnw(os.path.join(args.out_dir, "weights_tiny.bin"))
    spec = jax.ShapeDtypeStruct((3, net.input_h, net.input_w), jnp.uint8)
    for fname, use_pallas in [("model_tiny.hlo.txt", False), ("model_tiny_pallas.hlo.txt", True)]:
        lowered = jax.jit(
            lambda img, up=use_pallas: (snn_forward_quant(q, net, img, use_pallas=up),)
        ).lower(spec)
        hlo = to_hlo_text(lowered)
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(hlo)
        print(f"wrote {len(hlo)/1e6:.2f} MB → {fname}")
    # Refresh the cross-check vector and pin both graphs together.
    imgs, _ = read_snnd(os.path.join(args.out_dir, "dataset_test.bin"))
    ref = np.asarray(
        jax.jit(lambda im: snn_forward_quant(q, net, im, use_pallas=False))(jnp.asarray(imgs[0]))
    )
    pal = np.asarray(
        jax.jit(lambda im: snn_forward_quant(q, net, im, use_pallas=True))(jnp.asarray(imgs[0]))
    )
    assert (ref == pal).all(), "pallas and oracle graphs disagree"
    ref.astype("<i4").tofile(os.path.join(args.out_dir, "selfcheck_head_acc.bin"))
    print("selfcheck refreshed; pallas ≡ oracle confirmed")


if __name__ == "__main__":
    main()
