"""AOT build orchestrator — the single python entry point (`make artifacts`).

Pipeline (python runs ONCE; the rust binary is self-contained afterwards):

1. generate the synthetic IVS-3cls datasets (``dataset_train.bin`` /
   ``dataset_test.bin``, SNND format);
2. train the SNN detector (STBP + tdBN, mixed (1,3) time steps) and the
   Table-II comparison variants, logging the loss curve;
3. run the Table-I slimming pipeline: fine-grained pruning (+ masked
   fine-tune) → BN fold → 8-bit quantization → ``weights_tiny.bin``
   (SNNW format; also the unpruned quantization for ablation);
4. sweep the mixed-time-step configurations of Fig 15 (inference only);
5. lower the **quantized integer inference graph** (built from the
   Layer-1 Pallas kernels) to HLO **text** — not `.serialize()`: the
   image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos (see
   /opt/xla-example/README.md) — as ``model_tiny.hlo.txt`` for the rust
   PJRT runtime;
6. write ``metrics.json`` with every python-side number the rust benches
   print (Tables I/II, Fig 15, loss curve).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts \
        [--steps N] [--variant-steps N] [--quick] [--skip-variants]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, detect_np, train as T
from .binfmt import write_snnd, write_snnw
from .model import (
    build_network,
    fold_and_quantize,
    head_to_float,
    prune_fine_grained,
    snn_forward_quant,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format).

    ``print_large_constants=True`` is load-bearing: without it the printer
    elides big literals as ``{...}``, which the rust client's HLO parser
    silently mis-reads (the network's weights became garbage).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def eval_quant(qlayers, net, images, boxes, limit=None):
    """mAP of the quantized integer model (whole-image conv), via the same
    jitted graph that gets AOT-exported."""
    fwd = jax.jit(lambda img: snn_forward_quant(qlayers, net, img))
    t_in = net.layers[-1].in_t
    all_dets, all_gts = [], []
    n = len(images) if limit is None else min(limit, len(images))
    for i in range(n):
        acc = np.asarray(fwd(jnp.asarray(images[i])))
        head = head_to_float(acc, qlayers, t_in)
        all_dets.append(detect_np.nms(detect_np.decode(head)))
        all_gts.append(boxes[i])
    return detect_np.mean_ap(all_dets, all_gts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("SCSNN_STEPS", 240)))
    ap.add_argument(
        "--variant-steps", type=int, default=int(os.environ.get("SCSNN_VARIANT_STEPS", 120))
    )
    ap.add_argument("--train-images", type=int, default=192)
    ap.add_argument("--test-images", type=int, default=48)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true", help="smoke-test sizes")
    ap.add_argument("--skip-variants", action="store_true")
    ap.add_argument("--skip-fig15", action="store_true")
    args = ap.parse_args()
    if args.quick:
        args.steps, args.variant_steps = 8, 4
        args.train_images, args.test_images = 16, 8

    os.makedirs(args.out_dir, exist_ok=True)
    t_start = time.time()
    metrics: dict = {"config": vars(args).copy()}

    # ---- 1. datasets ----------------------------------------------------
    net = build_network("tiny", t=3, ts_mode="C2")
    w, h = net.input_w, net.input_h
    print(f"== datagen: {args.train_images}+{args.test_images} scenes {w}x{h}")
    tr_imgs, tr_boxes = datagen.generate(args.train_images, w, h, seed=args.seed)
    te_imgs, te_boxes = datagen.generate(args.test_images, w, h, seed=args.seed + 10_000)
    write_snnd(os.path.join(args.out_dir, "dataset_train.bin"), tr_imgs, tr_boxes)
    write_snnd(os.path.join(args.out_dir, "dataset_test.bin"), te_imgs, te_boxes)

    # ---- 2. train the SNN ------------------------------------------------
    print(f"== train SNN ({args.steps} steps)")
    params, bn, curve = T.train_model(
        net, tr_imgs, tr_boxes, args.steps, batch=args.batch, seed=args.seed, log="snn"
    )
    metrics["loss_curve"] = curve
    snn_a = T.evaluate_float(net, params, bn, te_imgs, te_boxes)
    print(f"   SNN-a (float) mAP = {snn_a['mean']:.3f}  per-class {snn_a['ap']}")

    # ---- 3. slimming pipeline (Table I) ----------------------------------
    print("== prune 80% of 3x3 kernels + masked fine-tune")
    pruned_params, masks = T.prune_float_params(params, net, rate=0.8)
    ft_steps = max(args.steps // 3, 1)
    gw, gh = net.grid()
    step_fn = T.make_masked_step_fn(net, masks)
    it = T.batches(tr_imgs, tr_boxes, args.batch, np.random.default_rng(args.seed + 1), gw, gh)
    opt = T.adam_init(pruned_params)
    # Fine-tune on a *separate copy* of the BN stats: `bn` stays paired
    # with the unpruned `params` for the later Fig 15 / SNN-4T inference
    # sweeps (mixing fine-tuned stats with unpruned weights zeroes them).
    bn_ft = {k: dict(v) for k, v in bn.items()}
    for s in range(ft_steps):
        imgs, obj, coords, cls = next(it)
        lr = T.lr_schedule(s, ft_steps, base=3e-4)
        loss, pruned_params, bn_ft, opt = step_fn(
            pruned_params, bn_ft, opt, jnp.float32(lr), imgs, obj, coords, cls
        )
    snn_b = T.evaluate_float(net, pruned_params, bn_ft, te_imgs, te_boxes)
    print(f"   SNN-b (pruned) mAP = {snn_b['mean']:.3f}")

    q_pruned = fold_and_quantize(pruned_params, bn_ft, net)
    # Re-apply the exact pruning mask after quantization (rounding must not
    # resurrect pruned weights).
    for name, m in masks.items():
        q_pruned[name].w *= np.asarray(m, np.int8).reshape(q_pruned[name].w.shape)
    q_dense = fold_and_quantize(params, bn, net)
    write_snnw(os.path.join(args.out_dir, "weights_tiny.bin"), q_pruned)
    write_snnw(os.path.join(args.out_dir, "weights_tiny_dense.bin"), q_dense)

    eval_n = None if args.test_images <= 48 else 48
    snn_c = eval_quant(q_pruned, net, te_imgs, te_boxes, limit=eval_n)
    print(f"   SNN-c (pruned+quant, int datapath) mAP = {snn_c['mean']:.3f}")
    metrics["table1"] = {
        "snn_a": snn_a,
        "snn_b": snn_b,
        "snn_c": snn_c,
        # SNN-d (block convolution) is evaluated by the rust golden model —
        # same quantized weights, 32×18 tiles. See benches/table1.rs.
        "params_dense": T.num_params(net),
        "nnz": int(sum(int((l.w != 0).sum()) for l in q_pruned.values())),
    }

    # ---- 4. Table II variants --------------------------------------------
    if not args.skip_variants:
        table2 = {}
        for label, variant, bits in [
            ("ann", "ann", 0),
            ("qnn4", "qnn", 4),
            ("qnn3", "qnn", 3),
            ("qnn2", "qnn", 2),
            ("bnn", "bnn", 0),
        ]:
            print(f"== train variant {label} ({args.variant_steps} steps)")
            vnet = build_network("tiny", t=3, ts_mode="C2")
            vp, vbn, _ = T.train_model(
                vnet,
                tr_imgs,
                tr_boxes,
                args.variant_steps,
                batch=args.batch,
                variant=variant,
                act_bits=bits or 4,
                seed=args.seed,
                log=label,
            )
            table2[label] = T.evaluate_float(
                vnet, vp, vbn, te_imgs, te_boxes, variant=variant, act_bits=bits or 4
            )
            print(f"   {label} mAP = {table2[label]['mean']:.3f}")
        # SNN-4T: same trained weights, (1,4) mixed time steps.
        net4 = build_network("tiny", t=4, ts_mode="C2")
        table2["snn_4t"] = T.evaluate_float(net4, params, bn, te_imgs, te_boxes)
        table2["snn_a"] = snn_a
        print(f"   snn_4t mAP = {table2['snn_4t']['mean']:.3f}")
        metrics["table2"] = table2

    # ---- 5. Fig 15 mixed-time-step sweep ----------------------------------
    if not args.skip_fig15:
        fig15 = {}
        for label, mode, blocks in [
            ("T3", "uniform", 0),
            ("C1", "C1", 0),
            ("C2", "C2", 0),
            ("C2B1", "C2B", 1),
            ("C2B2", "C2B", 2),
            ("C2B3", "C2B", 3),
        ]:
            snet = build_network("tiny", t=3, ts_mode=mode, ts_blocks=blocks)
            r = T.evaluate_float(snet, params, bn, te_imgs, te_boxes)
            fig15[label] = {"map": r, "giga_ops": T.dense_ops(snet) / 1e9}
            print(f"   fig15 {label}: mAP={r['mean']:.3f} ops={fig15[label]['giga_ops']:.2f}G")
        metrics["fig15"] = fig15

    # ---- 6. AOT-lower the quantized graph ---------------------------------
    # Two lowerings of the SAME integer network:
    # - the Pallas-kernel graph (the L1 contract; what pytest verifies) →
    #   `model_tiny_pallas.hlo.txt`;
    # - the lax.conv oracle graph → `model_tiny.hlo.txt`, the artifact the
    #   rust runtime loads. Both are bit-identical (asserted below); the
    #   oracle graph ships because interpret-mode Pallas lowers to
    #   per-grid-step while loops that xla_extension 0.5.1 (the rust
    #   client) compiles pathologically slowly.
    print("== lowering quantized inference graphs to HLO text")
    spec = jax.ShapeDtypeStruct((3, net.input_h, net.input_w), jnp.uint8)
    for fname, use_pallas in [("model_tiny.hlo.txt", False), ("model_tiny_pallas.hlo.txt", True)]:
        lowered = jax.jit(
            lambda img, up=use_pallas: (snn_forward_quant(q_pruned, net, img, use_pallas=up),)
        ).lower(spec)
        hlo = to_hlo_text(lowered)
        hlo_path = os.path.join(args.out_dir, fname)
        with open(hlo_path, "w") as f:
            f.write(hlo)
        print(f"   wrote {len(hlo)/1e6:.1f} MB HLO to {hlo_path}")

    # Cross-check vector for the rust integration test: head_acc of test
    # image 0 through the jitted graph — and pin the two graphs together.
    acc0 = np.asarray(jax.jit(
        lambda img: snn_forward_quant(q_pruned, net, img, use_pallas=False)
    )(jnp.asarray(te_imgs[0])))
    acc0_pallas = np.asarray(jax.jit(
        lambda img: snn_forward_quant(q_pruned, net, img, use_pallas=True)
    )(jnp.asarray(te_imgs[0])))
    assert (acc0 == acc0_pallas).all(), "pallas and oracle graphs disagree"
    np.asarray(acc0, "<i4").tofile(os.path.join(args.out_dir, "selfcheck_head_acc.bin"))
    metrics["selfcheck"] = {
        "image": 0,
        "head_shape": list(acc0.shape),
        "head_sum": int(acc0.astype(np.int64).sum()),
    }

    metrics["wall_seconds"] = time.time() - t_start
    with open(os.path.join(args.out_dir, "metrics.json"), "w") as f:
        json.dump(metrics, f, indent=1)
    print(f"== artifacts complete in {metrics['wall_seconds']:.0f}s → {args.out_dir}")


if __name__ == "__main__":
    main()
