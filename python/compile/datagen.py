"""Synthetic IVS-3cls-like driving scenes (build-path twin of
`rust/src/detect/dataset.rs`).

The real IVS 3cls dataset is proprietary; this generator produces the same
task shape — road scenes with perspective-scaled vehicles / bikes /
pedestrians and exact box ground truth — and writes the shared ``SNND``
format the rust request path reads. The scene *spec* matches the rust
generator (same classes, aspect ratios, perspective model); pixel-level
RNG differs, which is fine: rust consumes these files, it never needs to
re-generate identical pixels.
"""

from __future__ import annotations

import numpy as np

CLASS_NAMES = ("bike", "vehicle", "pedestrian")
NUM_CLASSES = 3


def synth_scene(w: int, h: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """One scene → (uint8 image (3,h,w), float32 boxes (n,5))."""
    img = np.zeros((3, h, w), np.float32)
    horizon = int(h * rng.uniform(0.35, 0.5))
    sky = rng.uniform([100, 140, 200], [160, 200, 255])
    road = rng.uniform(60, 110)
    # Sky gradient.
    t = (np.arange(horizon) / max(horizon, 1))[:, None]
    img[0, :horizon] = sky[0] * (1 - 0.3 * t)
    img[1, :horizon] = sky[1] * (1 - 0.2 * t)
    img[2, :horizon] = sky[2]
    # Road with mild depth shading.
    ys = np.arange(horizon, h)[:, None]
    shade = road + (ys - horizon) / 8.0
    img[0, horizon:] = shade
    img[1, horizon:] = shade
    img[2, horizon:] = shade + 5
    # Lane markings.
    for lane in range(3):
        x0 = w * (lane + 1) // 4
        for y in range(horizon, h - 4, 8):
            spread = (y - horizon) // 24 + 1
            img[:2, y : y + 3, max(0, x0 - spread // 2) : min(w, x0 + spread // 2 + 1)] = 230
            img[2, y : y + 3, max(0, x0 - spread // 2) : min(w, x0 + spread // 2 + 1)] = 200
    img += rng.uniform(-6, 6, size=img.shape)

    n_obj = rng.integers(1, 5)
    depths = np.sort(rng.uniform(0.25, 1.0, n_obj))
    boxes = []
    for depth in depths:
        cid = int(rng.integers(0, NUM_CLASSES))
        cy = horizon / h + depth * (1 - horizon / h) * 0.75
        scale = 0.3 + 0.7 * depth
        bw, bh = {
            0: (0.09 * scale, 0.15 * scale),
            1: (0.24 * scale, 0.16 * scale),
            2: (0.055 * scale, 0.20 * scale),
        }[cid]
        cx = rng.uniform(bw / 2 + 0.01, 1 - bw / 2 - 0.01)
        _draw_object(img, cid, cx, cy, bw, bh, rng)
        boxes.append((cid, cx, cy, bw, bh))
    return (
        np.clip(img, 0, 255).astype(np.uint8),
        np.asarray(boxes, np.float32).reshape(-1, 5),
    )


def _draw_object(img, cid, cx, cy, bw, bh, rng) -> None:
    _, h, w = img.shape
    x0, x1 = int((cx - bw / 2) * w), int((cx + bw / 2) * w)
    y0, y1 = int((cy - bh / 2) * h), int((cy + bh / 2) * h)
    x0, y0 = max(x0, 0), max(y0, 0)
    x1, y1 = min(x1, w), min(y1, h)
    if x1 <= x0 or y1 <= y0:
        return
    pw, ph = x1 - x0, y1 - y0

    def fill(ax0, ay0, ax1, ay1, c):
        ax0, ay0 = max(ax0, 0), max(ay0, 0)
        ax1, ay1 = min(ax1, w), min(ay1, h)
        if ax1 > ax0 and ay1 > ay0:
            img[:, ay0:ay1, ax0:ax1] = np.asarray(c, np.float32)[:, None, None]

    if cid == 0:  # bike: frame + two dark wheels
        c = rng.uniform([150, 40, 30], [230, 90, 80])
        fill(x0 + pw // 4, y0, x1 - pw // 4, y1 - ph // 3, c)
        fill(x0, y1 - ph // 3, x0 + pw // 3 + 1, y1, [20, 20, 20])
        fill(x1 - pw // 3 - 1, y1 - ph // 3, x1, y1, [20, 20, 20])
    elif cid == 1:  # vehicle: body + cabin + wheels
        c = rng.uniform(30, 220, 3)
        fill(x0, y0 + ph // 4, x1, y1 - ph // 6, c)
        fill(x0 + pw // 5, y0, x1 - pw // 5, y0 + ph // 4 + 1, c / 2)
        fill(x0 + pw // 8, y1 - ph // 6, x0 + pw // 4, y1, [15, 15, 15])
        fill(x1 - pw // 4, y1 - ph // 6, x1 - pw // 8, y1, [15, 15, 15])
    else:  # pedestrian: body column + head
        c = rng.uniform([140, 100, 60], [220, 180, 140])
        fill(x0, y0 + ph // 5, x1, y1, c)
        fill(x0 + pw // 4, y0, x1 - pw // 4, y0 + ph // 5 + 1, [224, 180, 150])


def generate(n: int, w: int, h: int, seed: int) -> tuple[list[np.ndarray], list[np.ndarray]]:
    """Generate ``n`` scenes."""
    rng = np.random.default_rng(seed)
    images, boxes = [], []
    for _ in range(n):
        img, bxs = synth_scene(w, h, rng)
        images.append(img)
        boxes.append(bxs)
    return images, boxes
