"""Build-time training pipeline (§II / §IV-A).

Trains the SNN detector with STBP + tdBN on the synthetic IVS-3cls stand-in,
applies the model-slimming steps of Table I (fine-grained pruning →
8-bit quantization; block convolution is evaluated on the rust side), and
trains the ANN/QNN/BNN comparison variants of Table II. Emits
``metrics.json`` with the loss curve and every python-side mAP so the rust
benches can print the paper tables.

This module is build-path only — it never runs at inference time.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import detect_np
from .model import (
    ANCHORS,
    HEAD_CH,
    NUM_CLASSES,
    NetworkSpec,
    build_network,
    fold_and_quantize,
    init_bn_stats,
    init_params,
    prune_fine_grained,
    snn_forward_float,
    variant_forward,
)

LAMBDA_COORD = 5.0
LAMBDA_NOOBJ = 0.3


# --------------------------------------------------------------------------
# YOLOv2 target assignment + loss
# --------------------------------------------------------------------------


def assign_targets(boxes: np.ndarray, gw: int, gh: int):
    """Build dense YOLO targets for one image.

    Returns (obj (A,gh,gw), coords (A,4,gh,gw), cls (A,gh,gw) int)."""
    na = len(ANCHORS)
    obj = np.zeros((na, gh, gw), np.float32)
    coords = np.zeros((na, 4, gh, gw), np.float32)
    cls = np.zeros((na, gh, gw), np.int32)
    for row in boxes:
        cid, cx, cy, bw, bh = row
        j = min(int(cx * gw), gw - 1)
        i = min(int(cy * gh), gh - 1)
        # Best anchor by shape IoU in grid units.
        tw_g, th_g = bw * gw, bh * gh
        best_a, best_iou = 0, -1.0
        for a, (pw, ph) in enumerate(ANCHORS):
            inter = min(tw_g, pw) * min(th_g, ph)
            union = tw_g * th_g + pw * ph - inter
            v = inter / union
            if v > best_iou:
                best_a, best_iou = a, v
        pw, ph = ANCHORS[best_a]
        tx = np.clip(cx * gw - j, 1e-4, 1 - 1e-4)
        ty = np.clip(cy * gh - i, 1e-4, 1 - 1e-4)
        obj[best_a, i, j] = 1.0
        coords[best_a, :, i, j] = (
            np.log(tx / (1 - tx)),
            np.log(ty / (1 - ty)),
            np.log(max(tw_g / pw, 1e-6)),
            np.log(max(th_g / ph, 1e-6)),
        )
        cls[best_a, i, j] = int(cid)
    return obj, coords, cls


def yolo_loss(head: jnp.ndarray, obj, coords, cls):
    """YOLOv2-style loss on a batch. ``head``: (B, HEAD_CH, gh, gw)."""
    b, _, gh, gw = head.shape
    na = len(ANCHORS)
    per = 5 + NUM_CLASSES
    h = head.reshape(b, na, per, gh, gw)
    pred_xy = h[:, :, 0:2]
    pred_wh = h[:, :, 2:4]
    pred_obj = h[:, :, 4]
    pred_cls = h[:, :, 5:]

    m = obj[:, :, None]  # (B,A,1,gh,gw)
    coord_loss = (
        LAMBDA_COORD
        * (m * ((pred_xy - coords[:, :, 0:2]) ** 2 + (pred_wh - coords[:, :, 2:4]) ** 2)).sum()
    )
    # BCE with logits on objectness.
    bce = jnp.maximum(pred_obj, 0) - pred_obj * obj + jnp.log1p(jnp.exp(-jnp.abs(pred_obj)))
    obj_loss = (obj * bce).sum() + LAMBDA_NOOBJ * ((1 - obj) * bce).sum()
    # Cross-entropy on matched cells.
    logp = jax.nn.log_softmax(pred_cls, axis=2)
    onehot = jax.nn.one_hot(cls, NUM_CLASSES, axis=2, dtype=head.dtype)
    cls_loss = -(obj[:, :, None] * onehot * logp).sum()
    n_pos = jnp.maximum(obj.sum(), 1.0)
    return (coord_loss + obj_loss + cls_loss) / n_pos


# --------------------------------------------------------------------------
# Minimal Adam (optax unavailable offline)
# --------------------------------------------------------------------------


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, wd=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2**t), v)
    # AdamW-style decoupled weight decay (the paper uses AdamW).
    new_params = jax.tree.map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p), params, mhat, vhat
    )
    return new_params, {"m": m, "v": v, "t": t}


def lr_schedule(step: int, total: int, base: float = 2e-3) -> float:
    """Warmup from base/100 over the first 5% then cosine to base/100."""
    warm = max(total // 20, 1)
    if step < warm:
        return base * (0.01 + 0.99 * step / warm)
    p = (step - warm) / max(total - warm, 1)
    return base * (0.01 + 0.99 * 0.5 * (1 + np.cos(np.pi * p)))


# --------------------------------------------------------------------------
# Training / evaluation drivers
# --------------------------------------------------------------------------


def batches(images, boxes, batch, rng, gw, gh):
    """Endless shuffled minibatches of (imgs float [0,1], targets)."""
    n = len(images)
    order = rng.permutation(n)
    i = 0
    while True:
        if i + batch > n:
            order = rng.permutation(n)
            i = 0
        idx = order[i : i + batch]
        i += batch
        imgs = np.stack([images[k] for k in idx]).astype(np.float32) / 255.0
        tgt = [assign_targets(boxes[k], gw, gh) for k in idx]
        obj = np.stack([t[0] for t in tgt])
        coords = np.stack([t[1] for t in tgt])
        cls = np.stack([t[2] for t in tgt])
        yield jnp.asarray(imgs), jnp.asarray(obj), jnp.asarray(coords), jnp.asarray(cls)


def make_step_fn(net: NetworkSpec, variant: str | None, act_bits: int = 4):
    """Jitted (params, bn, batch) → (loss, params, bn) train step."""

    def loss_fn(params, bn, imgs, obj, coords, cls):
        if variant is None:
            head, new_bn, _ = snn_forward_float(params, bn, net, imgs, train=True)
        else:
            head, new_bn = variant_forward(
                params, bn, net, imgs, variant=variant, act_bits=act_bits, train=True
            )
        return yolo_loss(head, obj, coords, cls), new_bn

    @jax.jit
    def step(params, bn, opt, lr, imgs, obj, coords, cls):
        (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, bn, imgs, obj, coords, cls
        )
        params, opt = adam_update(params, grads, opt, lr)
        return loss, params, new_bn, opt

    return step


def make_masked_step_fn(net: NetworkSpec, masks):
    """Train step that keeps pruned weights at zero (fine-tuning)."""

    def loss_fn(params, bn, imgs, obj, coords, cls):
        mp = {
            k: {**v, "w": v["w"] * masks[k]} if "w" in v else v for k, v in params.items()
        }
        head, new_bn, _ = snn_forward_float(mp, bn, net, imgs, train=True)
        return yolo_loss(head, obj, coords, cls), new_bn

    @jax.jit
    def step(params, bn, opt, lr, imgs, obj, coords, cls):
        (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, bn, imgs, obj, coords, cls
        )
        grads = {
            k: {kk: (vv * masks[k] if kk == "w" else vv) for kk, vv in v.items()}
            for k, v in grads.items()
        }
        params, opt = adam_update(params, grads, opt, lr)
        params = {
            k: {kk: (vv * masks[k] if kk == "w" else vv) for kk, vv in v.items()}
            for k, v in params.items()
        }
        return loss, params, new_bn, opt

    return step


def train_model(net, images, boxes, steps, batch=4, variant=None, act_bits=4, seed=0, log=None):
    """Train one model; returns (params, bn_stats, loss_curve)."""
    gw, gh = net.grid()
    params = init_params(net, seed)
    bn = init_bn_stats(net)
    opt = adam_init(params)
    step_fn = make_step_fn(net, variant, act_bits)
    it = batches(images, boxes, batch, np.random.default_rng(seed), gw, gh)
    curve = []
    t0 = time.time()
    for s in range(steps):
        imgs, obj, coords, cls = next(it)
        lr = lr_schedule(s, steps)
        loss, params, bn, opt = step_fn(params, bn, opt, jnp.float32(lr), imgs, obj, coords, cls)
        curve.append(float(loss))
        if log and (s % max(steps // 10, 1) == 0 or s == steps - 1):
            print(f"[{log}] step {s}/{steps} loss={float(loss):.4f} ({time.time()-t0:.0f}s)")
    return params, bn, curve


def evaluate_float(net, params, bn, images, boxes, variant=None, act_bits=4, batch=8):
    """mAP of a float model on a dataset."""

    @jax.jit
    def fwd(imgs):
        if variant is None:
            head, _, _ = snn_forward_float(params, bn, net, imgs, train=False)
        else:
            head, _ = variant_forward(
                params, bn, net, imgs, variant=variant, act_bits=act_bits, train=False
            )
        return head

    all_dets, all_gts = [], []
    for i in range(0, len(images), batch):
        imgs = np.stack(images[i : i + batch]).astype(np.float32) / 255.0
        heads = np.asarray(fwd(jnp.asarray(imgs)))
        for bidx in range(heads.shape[0]):
            dets = detect_np.nms(detect_np.decode(heads[bidx]))
            all_dets.append(dets)
            all_gts.append(boxes[i + bidx])
    return detect_np.mean_ap(all_dets, all_gts)


def prune_float_params(params, net, rate=0.8):
    """Magnitude-prune 3×3 layers in the float domain; returns (params,
    masks)."""
    out, masks = {}, {}
    for l in net.layers:
        p = dict(params[l.name])
        w = np.asarray(p["w"])
        if l.k > 1:
            mags = np.sort(np.abs(w).ravel())
            thr = mags[min(int(len(mags) * rate), len(mags) - 1)]
            mask = (np.abs(w) >= max(thr, 1e-12)).astype(np.float32)
        else:
            mask = np.ones_like(w, np.float32)
        p["w"] = jnp.asarray(w * mask)
        out[l.name] = p
        masks[l.name] = jnp.asarray(mask)
    return out, masks


def dense_ops(net: NetworkSpec) -> int:
    """Dense operation count (2 ops/MAC), mirroring rust
    `NetworkSpec::dense_ops`."""
    total = 0
    for l in net.layers:
        planes = 8 if l.kind == "encoding" else 1
        total += 2 * l.c_out * l.c_in * l.k * l.k * l.in_w * l.in_h * l.in_t * planes
    return total


def num_params(net: NetworkSpec) -> int:
    """Parameter count (weights + biases)."""
    return sum(l.c_out * l.c_in * l.k * l.k + l.c_out for l in net.layers)
