"""Layer-1 Pallas kernel: the LIF membrane update (Fig 7's LIF module).

One grid instance advances one time step's worth of neurons for a channel
block: leak (truncate-toward-zero ×0.25 shift), integrate, compare against
``vth_q``, hard reset, 8-bit saturating membrane store — exactly the
datapath of the chip's LIF unit and of ``ref.lif_chain``.

The time recurrence stays outside (a `lax.scan` in the L2 model): membrane
state is carried as a kernel input/output pair, mirroring the hardware's
vmem registers being read and written every step.

``interpret=True`` for CPU-PJRT executability (see gated_conv.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import I8_MAX, I8_MIN


def _kernel(acc_ref, vmem_ref, fired_ref, vth_ref, out_spike_ref, out_vmem_ref, out_fired_ref):
    """One LIF step over a flat neuron block."""
    vmem = vmem_ref[...]
    acc = acc_ref[...]
    fired = fired_ref[...]
    residual = jnp.where(fired != 0, 0, vmem)
    leaked = jnp.where(residual >= 0, residual >> 2, -((-residual) >> 2))
    u = leaked + acc
    s = (u >= vth_ref[0]).astype(jnp.int32)
    out_spike_ref[...] = s
    out_vmem_ref[...] = jnp.clip(u, I8_MIN, I8_MAX)
    out_fired_ref[...] = s


@jax.jit
def lif_step(
    acc: jnp.ndarray, vmem: jnp.ndarray, fired: jnp.ndarray, vth_q: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One LIF time step via the Pallas kernel.

    All arrays int32, any (flattenable) shape; ``vth_q`` scalar int32 array.
    Returns ``(spikes, new_vmem, new_fired)``.
    """
    shape = acc.shape
    flat = lambda a: a.reshape(-1).astype(jnp.int32)
    n = acc.size
    spikes, new_vmem, new_fired = pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(flat(acc), flat(vmem), flat(fired), jnp.atleast_1d(vth_q).astype(jnp.int32))
    return spikes.reshape(shape), new_vmem.reshape(shape), new_fired.reshape(shape)


def lif_chain_pallas(accs: jnp.ndarray, vth_q) -> jnp.ndarray:
    """LIF over a (T, …) stack using the Pallas step kernel.

    Matches ``ref.lif_chain`` bit-exactly.
    """
    def step(carry, acc):
        vmem, fired = carry
        spikes, vmem, fired = lif_step(acc, vmem, fired, jnp.asarray(vth_q, jnp.int32))
        return (vmem, fired), spikes

    zero = jnp.zeros(accs.shape[1:], jnp.int32)
    _, spikes = jax.lax.scan(step, (zero, zero), accs)
    return spikes
