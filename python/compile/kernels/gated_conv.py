"""Layer-1 Pallas kernel: the gated one-to-all product (§III-B-1).

One grid instance computes one output channel of a spike-conv layer over
the whole resident tile: for every kernel position ``(r, c)`` the input
window shifted by ``(r−1, c−1)`` (the *enable map*) gates the accumulation
of that position's weight across all output neurons in parallel — a
scatter-free sparse convolution.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the 28nm ASIC skips
zero weights in *time* (one cycle per nonzero). A TPU kernel has static
shapes, so the skip becomes a *multiply-free masked accumulate*: zero
weights contribute nothing and the VPU processes the whole enable map per
step; cycle-level skipping is modeled by the rust simulator instead. The
input tile stays resident in VMEM across all kernel positions and output
channels (BlockSpec pins it), mirroring the Input-SRAM residency of the
chip; weights stream per output channel like the NZ-Weight SRAM reads.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which both pytest and
the rust runtime execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import sat_i16


def _kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int):
    """One output channel: gated one-to-all accumulation.

    ``x_ref``: (C, H+2ph, W+2pw) int32 replicate-padded spikes (VMEM);
    ``w_ref``: (C, kh, kw) int32 weights for this output channel;
    ``b_ref``: (1,) int32 bias; ``o_ref``: (H, W) int32 accumulator out.
    """
    c_in = x_ref.shape[0]
    h, w = o_ref.shape
    acc = jnp.full((h, w), b_ref[0], jnp.int32)
    # Python loops unroll at trace time: kh·kw·C static steps, matching the
    # KTBC inner loop (C innermost is the hardware order; any order is
    # associative here).
    for r in range(kh):
        for col in range(kw):
            for c in range(c_in):
                enable = x_ref[c, r : r + h, col : col + w]
                acc = acc + enable * w_ref[c, r, col]
    o_ref[...] = sat_i16(acc)


@functools.partial(jax.jit, static_argnames=("kh", "kw"))
def gated_conv2d(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray, *, kh: int, kw: int) -> jnp.ndarray:
    """Gated one-to-all convolution of a full layer.

    ``x``: int32 (C, H, W) spikes (or pixels/bit planes); ``w``: int32
    (K, C, kh, kw); ``bias``: int32 (K,). Returns int32 (K, H, W) 16-bit
    saturated accumulators — bit-exact with ``ref.conv2d_int``.
    """
    c_in, h, width = x.shape
    k = w.shape[0]
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x.astype(jnp.int32), ((0, 0), (ph, ph), (pw, pw)), mode="edge")
    kernel = functools.partial(_kernel, kh=kh, kw=kw)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            # Input tile resident across the whole grid (VMEM pinning).
            pl.BlockSpec((c_in, h + 2 * ph, width + 2 * pw), lambda i: (0, 0, 0)),
            # One output channel's weights per grid step (leading dim
            # squeezed away inside the kernel).
            pl.BlockSpec((None, c_in, kh, kw), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((None, h, width), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, h, width), jnp.int32),
        interpret=True,
    )(xp, w.astype(jnp.int32), bias.astype(jnp.int32))
