"""Pure-jnp correctness oracles for the Pallas kernels.

Semantics are the project-wide integer datapath contract (see
`rust/src/ref_impl/conv.rs`):

- stride-1 same-size convolution, **replicate** boundary padding;
- int32 accumulation, saturation to the PE's 16-bit domain at the end of
  each conv;
- LIF: ``u[t] = leak(u[t-1]·(1−s[t-1])) + I[t]``, ``s = u ≥ vth``, with the
  hardware leak (×0.25 as a truncate-toward-zero shift) and 8-bit
  saturating membrane storage.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

I16_MIN, I16_MAX = -(2**15), 2**15 - 1
I8_MIN, I8_MAX = -128, 127


def sat_i16(x: jnp.ndarray) -> jnp.ndarray:
    """Saturate int32 to the 16-bit accumulator domain."""
    return jnp.clip(x, I16_MIN, I16_MAX)


def sat_i8(x: jnp.ndarray) -> jnp.ndarray:
    """Saturate int32 to 8-bit membrane storage."""
    return jnp.clip(x, I8_MIN, I8_MAX)


def leak(v: jnp.ndarray) -> jnp.ndarray:
    """The hardware leak: ×0.25 as an arithmetic shift truncating toward
    zero (`QuantParams::leak` in rust)."""
    return jnp.where(v >= 0, v >> 2, -((-v) >> 2))


def conv2d_int(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """Integer same-size conv with replicate padding.

    ``x``: int32 (C, H, W); ``w``: int32 (K, C, kh, kw); ``bias``: int32
    (K,). Returns int32 (K, H, W), 16-bit saturated.
    """
    kh, kw = w.shape[2], w.shape[3]
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw)), mode="edge")
    # Compute in f32 and cast back: every accumulator in this network is
    # bounded by c_in·k²·127·255 < 2²⁴, so f32 is exact — and float conv
    # is the only convolution the rust client's xla_extension 0.5.1
    # compiles correctly (integer conv miscompiles there; the pytest
    # oracle tests pin exactness against the integer Pallas kernels).
    out = lax.conv_general_dilated(
        xp[None].astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0].astype(jnp.int32)
    return sat_i16(out + bias[:, None, None])


def lif_chain(accs: jnp.ndarray, vth_q) -> jnp.ndarray:
    """Run the LIF over a (T, …) stack of integer conv results.

    Returns spikes (T, …) int32 ∈ {0,1}.
    """

    def step(carry, acc):
        vmem, fired = carry
        residual = jnp.where(fired, 0, vmem)
        u = leak(residual) + acc
        s = u >= vth_q
        return (sat_i8(u), s), s.astype(jnp.int32)

    zero = jnp.zeros(accs.shape[1:], jnp.int32)
    _, spikes = lax.scan(step, (zero, zero.astype(bool)), accs)
    return spikes


def maxpool2x2_or(x: jnp.ndarray) -> jnp.ndarray:
    """2×2 stride-2 OR pooling on a binary (C, H, W) map."""
    c, h, w = x.shape
    x = x[:, : h // 2 * 2, : w // 2 * 2]
    x = x.reshape(c, h // 2, 2, w // 2, 2)
    return (x.sum(axis=(2, 4)) > 0).astype(jnp.int32)
