"""NumPy detection utilities for the build path: YOLOv2 decode, NMS, and
VOC-style mAP — the python twin of `rust/src/detect/` (same formulas) so
``train.py`` can report Table I/II metrics without the rust binary.
"""

from __future__ import annotations

import numpy as np

from .model import ANCHORS, NUM_CLASSES


def _sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


def decode(head: np.ndarray, conf_thresh: float = 0.1) -> np.ndarray:
    """Decode a head map (HEAD_CH, gh, gw) → (n, 6) rows of
    ``(class_id, cx, cy, w, h, score)``."""
    per = 5 + NUM_CLASSES
    gh, gw = head.shape[1], head.shape[2]
    dets = []
    for a, (pw, ph) in enumerate(ANCHORS):
        blk = head[a * per : (a + 1) * per]
        obj = _sigmoid(blk[4])
        logits = blk[5:]
        logits = logits - logits.max(axis=0, keepdims=True)
        probs = np.exp(logits)
        probs /= probs.sum(axis=0, keepdims=True)
        cls = probs.argmax(axis=0)
        score = obj * probs.max(axis=0)
        ii, jj = np.nonzero(score >= conf_thresh)
        for i, j in zip(ii, jj):
            bw = min(pw * np.exp(np.clip(blk[2, i, j], -6, 6)) / gw, 1.0)
            bh = min(ph * np.exp(np.clip(blk[3, i, j], -6, 6)) / gh, 1.0)
            dets.append(
                (
                    cls[i, j],
                    (j + _sigmoid(blk[0, i, j])) / gw,
                    (i + _sigmoid(blk[1, i, j])) / gh,
                    bw,
                    bh,
                    score[i, j],
                )
            )
    return np.asarray(dets, np.float64).reshape(-1, 6)


def iou(a: np.ndarray, b: np.ndarray) -> float:
    """IoU of two (cx, cy, w, h) boxes."""
    ax0, ay0, ax1, ay1 = a[0] - a[2] / 2, a[1] - a[3] / 2, a[0] + a[2] / 2, a[1] + a[3] / 2
    bx0, by0, bx1, by1 = b[0] - b[2] / 2, b[1] - b[3] / 2, b[0] + b[2] / 2, b[1] + b[3] / 2
    ix = max(0.0, min(ax1, bx1) - max(ax0, bx0))
    iy = max(0.0, min(ay1, by1) - max(ay0, by0))
    inter = ix * iy
    union = a[2] * a[3] + b[2] * b[3] - inter
    return inter / union if union > 0 else 0.0


def nms(dets: np.ndarray, iou_thresh: float = 0.45) -> np.ndarray:
    """Greedy per-class NMS on (n, 6) rows."""
    if len(dets) == 0:
        return dets
    order = np.argsort(-dets[:, 5])
    keep = []
    for idx in order:
        d = dets[idx]
        if any(k[0] == d[0] and iou(k[1:5], d[1:5]) > iou_thresh for k in keep):
            continue
        keep.append(d)
    return np.asarray(keep).reshape(-1, 6)


def average_precision(dets, gts, iou_thresh=0.5) -> float:
    """AP for one class. ``dets``: list of (img, row6); ``gts``: list of
    (img, row5)."""
    if not gts:
        return 1.0 if not dets else 0.0
    dets = sorted(dets, key=lambda d: -d[1][5])
    matched = [False] * len(gts)
    tp, fp = [], []
    for img, d in dets:
        best, best_iou = None, 0.0
        for gi, (gimg, g) in enumerate(gts):
            if gimg != img or matched[gi]:
                continue
            v = iou(d[1:5], g[1:5])
            if v >= iou_thresh and v > best_iou:
                best, best_iou = gi, v
        if best is not None:
            matched[best] = True
            tp.append(1)
            fp.append(0)
        else:
            tp.append(0)
            fp.append(1)
    tp = np.cumsum(tp)
    fp = np.cumsum(fp)
    recall = tp / len(gts)
    precision = tp / np.maximum(tp + fp, 1)
    # All-points interpolation.
    for i in range(len(precision) - 2, -1, -1):
        precision[i] = max(precision[i], precision[i + 1])
    ap, prev_r = 0.0, 0.0
    for r, p in zip(recall, precision):
        ap += (r - prev_r) * p
        prev_r = r
    return float(ap)


def mean_ap(all_dets, all_gts, iou_thresh=0.5) -> dict:
    """mAP over the dataset. ``all_dets[i]``: (n,6) per image; ``all_gts[i]``:
    (m,5) per image. Returns {"ap": [per class], "mean": float}."""
    aps = []
    for c in range(NUM_CLASSES):
        d = [(i, row) for i, rows in enumerate(all_dets) for row in rows if int(row[0]) == c]
        g = [(i, row) for i, rows in enumerate(all_gts) for row in rows if int(row[0]) == c]
        aps.append(average_precision(d, g, iou_thresh))
    return {"ap": aps, "mean": float(np.mean(aps))}
