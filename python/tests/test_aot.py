"""AOT path checks: HLO-text lowering of pallas-bearing graphs (the
interchange contract with the rust runtime)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import to_hlo_text
from compile.kernels.gated_conv import gated_conv2d


def test_to_hlo_text_plain_fn():
    lowered = jax.jit(lambda x, y: (x @ y + 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32), jax.ShapeDtypeStruct((2, 2), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "dot(" in text or "dot " in text


def test_to_hlo_text_pallas_kernel_no_custom_calls():
    """interpret=True pallas must lower to plain HLO — no Mosaic custom
    calls, or the rust CPU PJRT client cannot execute the artifact."""
    w = jnp.asarray(np.random.default_rng(0).integers(-5, 5, (2, 3, 3, 3)), jnp.int32)
    b = jnp.zeros((2,), jnp.int32)
    lowered = jax.jit(lambda x: (gated_conv2d(x, w, b, kh=3, kw=3),)).lower(
        jax.ShapeDtypeStruct((3, 8, 8), jnp.int32)
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "custom-call" not in text.lower(), "Mosaic custom call leaked into AOT HLO"


def test_hlo_text_declares_expected_interface():
    """The exported HLO must expose the uint8 image parameter and an int32
    tuple result — the interface the rust runtime programs against. (The
    numeric roundtrip through `HloModuleProto::from_text_file` is covered
    by the rust integration test `tests/runtime_roundtrip.rs`.)"""
    w = jnp.asarray(np.random.default_rng(1).integers(-5, 5, (2, 2, 1, 1)), jnp.int32)
    b = jnp.asarray([3, -4], jnp.int32)
    fn = lambda x: (gated_conv2d(x.astype(jnp.int32), w, b, kh=1, kw=1),)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 4, 4), jnp.uint8))
    text = to_hlo_text(lowered)
    assert "u8[2,4,4]" in text, "uint8 image parameter missing"
    assert "s32[2,4,4]" in text, "int32 head output missing"
    assert text.count("ENTRY") == 1
