"""SNNW / SNND artifact format roundtrips (the rust side re-verifies the
same bytes in its integration tests)."""

import numpy as np

from compile.binfmt import QuantLayer, read_snnd, read_snnw, write_snnd, write_snnw
from compile import datagen


def test_snnw_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    layers = {
        "enc": QuantLayer(
            w=rng.integers(-128, 128, (4, 3, 3, 3)).astype(np.int8),
            bias=rng.integers(-1000, 1000, (4,)).astype(np.int32),
            scale=0.0123,
            vth_q=41,
        ),
        "head": QuantLayer(
            w=rng.integers(-128, 128, (40, 8, 1, 1)).astype(np.int8),
            bias=np.zeros((40,), np.int32),
            scale=0.5,
            vth_q=1,
        ),
    }
    p = str(tmp_path / "w.bin")
    write_snnw(p, layers)
    back = read_snnw(p)
    assert set(back) == set(layers)
    for k in layers:
        np.testing.assert_array_equal(back[k].w, layers[k].w)
        np.testing.assert_array_equal(back[k].bias, layers[k].bias)
        assert back[k].vth_q == layers[k].vth_q
        assert abs(back[k].scale - layers[k].scale) < 1e-6


def test_snnd_roundtrip(tmp_path):
    imgs, boxes = datagen.generate(3, 64, 48, seed=1)
    p = str(tmp_path / "d.bin")
    write_snnd(p, imgs, boxes)
    bi, bb = read_snnd(p)
    assert len(bi) == 3
    for a, b in zip(imgs, bi):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(boxes, bb):
        np.testing.assert_allclose(a, b, rtol=1e-6)


def test_datagen_boxes_in_bounds():
    imgs, boxes = datagen.generate(5, 96, 64, seed=2)
    for img, bxs in zip(imgs, boxes):
        assert img.shape == (3, 64, 96) and img.dtype == np.uint8
        assert len(bxs) >= 1
        for cid, cx, cy, w, h in bxs:
            assert 0 <= cid < 3
            assert 0 <= cx - w / 2 and cx + w / 2 <= 1
            assert 0 <= cy - h / 2 and cy + h / 2 <= 1


def test_datagen_deterministic():
    a, _ = datagen.generate(2, 48, 32, seed=3)
    b, _ = datagen.generate(2, 48, 32, seed=3)
    np.testing.assert_array_equal(a[0], b[0])
