"""Training-path checks: target assignment, loss behaviour on an
overfittable micro-batch, detection metrics, pruning."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import datagen, detect_np
from compile import train as T
from compile.model import ANCHORS, build_network, init_bn_stats, init_params


def test_assign_targets_marks_best_anchor():
    boxes = np.asarray([[1, 0.55, 0.55, 0.3, 0.2]], np.float32)  # wide vehicle
    obj, coords, cls = T.assign_targets(boxes, gw=10, gh=6)
    assert obj.sum() == 1.0
    a, i, j = np.unravel_index(obj.argmax(), obj.shape)
    assert (i, j) == (3, 5)
    assert cls[a, i, j] == 1
    # The matched anchor's prior should be among the wider ones.
    assert ANCHORS[a][0] >= 1.0


def test_yolo_loss_zero_when_perfect():
    gw, gh = 10, 6
    boxes = np.asarray([[2, 0.35, 0.45, 0.1, 0.2]], np.float32)
    obj, coords, cls = T.assign_targets(boxes, gw, gh)
    na, per = len(ANCHORS), 5 + 3
    head = np.zeros((1, na * per, gh, gw), np.float32)
    h = head.reshape(1, na, per, gh, gw)
    # Perfect prediction: exact coords, +inf/-inf objectness and classes.
    h[0, :, 4] = -30.0
    a, i, j = np.unravel_index(obj.argmax(), obj.shape)
    h[0, a, 0:4, i, j] = coords[a, :, i, j]
    h[0, a, 4, i, j] = 30.0
    h[0, a, 5 + int(cls[a, i, j]), i, j] = 30.0
    loss = T.yolo_loss(
        jnp.asarray(head), jnp.asarray(obj[None]), jnp.asarray(coords[None]), jnp.asarray(cls[None])
    )
    assert float(loss) < 1e-3


def test_loss_decreases_on_overfit():
    net = build_network("tiny")
    imgs, boxes = datagen.generate(2, net.input_w, net.input_h, seed=5)
    _, _, curve = T.train_model(net, imgs, boxes, steps=14, batch=2, seed=0)
    # Compare early vs late averages (noisy, so use windows).
    early = np.mean(curve[:4])
    late = np.mean(curve[-4:])
    assert late < early, f"loss did not decrease: {early} -> {late}"


def test_prune_keeps_1x1_dense():
    net = build_network("tiny")
    params = init_params(net, 1)
    pruned, masks = T.prune_float_params(params, net, rate=0.8)
    short = np.asarray(masks["b1.short"])
    assert short.min() == 1.0
    enc = np.asarray(masks["enc"])
    assert enc.mean() < 0.35


def test_decode_nms_ap_pipeline():
    # Synthesize a perfect head for one GT box and check AP = 1.
    from compile.model import HEAD_CH, NUM_CLASSES

    gw, gh = 10, 6
    boxes = np.asarray([[0, 0.32, 0.52, 0.12, 0.18]], np.float32)
    obj, coords, cls = T.assign_targets(boxes, gw, gh)
    na, per = len(ANCHORS), 5 + NUM_CLASSES
    head = np.full((na * per, gh, gw), -20.0, np.float32)
    h = head.reshape(na, per, gh, gw)
    a, i, j = np.unravel_index(obj.argmax(), obj.shape)
    h[a, 0:4, i, j] = coords[a, :, i, j]
    h[a, 4, i, j] = 20.0
    h[a, 5 + 0, i, j] = 20.0
    dets = detect_np.nms(detect_np.decode(head))
    assert len(dets) == 1
    r = detect_np.mean_ap([dets], [boxes])
    assert r["ap"][0] == 1.0


def test_lr_schedule_warmup_and_decay():
    total = 100
    lrs = [T.lr_schedule(s, total) for s in range(total)]
    assert lrs[0] < lrs[5] <= max(lrs)
    assert lrs[-1] < max(lrs) / 10
