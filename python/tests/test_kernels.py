"""Layer-1 kernel correctness: the Pallas gated one-to-all product and LIF
kernel against the pure-jnp oracle — the core correctness signal of the
build path. Hypothesis sweeps shapes, densities and kernel sizes."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gated_conv import gated_conv2d
from compile.kernels.lif import lif_chain_pallas, lif_step
from compile.kernels.ref import conv2d_int, leak, lif_chain, maxpool2x2_or, sat_i16


def rand_case(rng, c, k, h, w, kh, density):
    x = (rng.random((c, h, w)) < 0.4).astype(np.int32)
    mask = rng.random((k, c, kh, kh)) < density
    wgt = (rng.integers(-30, 31, (k, c, kh, kh)) * mask).astype(np.int32)
    b = rng.integers(-50, 51, (k,)).astype(np.int32)
    return x, wgt, b


@settings(max_examples=12, deadline=None)
@given(
    c=st.integers(1, 6),
    k=st.integers(1, 5),
    h=st.integers(1, 12),
    w=st.integers(1, 12),
    kh=st.sampled_from([1, 3]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31),
)
def test_gated_conv_matches_oracle(c, k, h, w, kh, density, seed):
    x, wgt, b = rand_case(np.random.default_rng(seed), c, k, h, w, kh, density)
    got = gated_conv2d(jnp.asarray(x), jnp.asarray(wgt), jnp.asarray(b), kh=kh, kw=kh)
    want = conv2d_int(jnp.asarray(x), jnp.asarray(wgt), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gated_conv_multibit_bit_serial_equivalence():
    """Σ_b (conv of bit plane b) << b  ==  conv of the multibit input —
    the encoding layer's bit-serial contract (§III-C)."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (3, 8, 10)).astype(np.int32)
    w = rng.integers(-10, 11, (4, 3, 3, 3)).astype(np.int32)
    b = np.zeros((4,), np.int32)
    direct = gated_conv2d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), kh=3, kw=3)
    acc = np.zeros_like(np.asarray(direct))
    for bit in range(8):
        plane = (x >> bit) & 1
        conv = np.asarray(
            gated_conv2d(jnp.asarray(plane), jnp.asarray(w), jnp.asarray(b), kh=3, kw=3)
        )
        acc += conv << bit
    # Bit-serial sums in int32; saturate once at the end like the PE readout.
    np.testing.assert_array_equal(np.clip(acc, -(2**15), 2**15 - 1), np.asarray(direct))


def test_gated_conv_saturates():
    x = np.ones((1, 2, 2), np.int32)
    w = np.full((1, 1, 3, 3), 127, np.int32) * 300  # force overflow
    b = np.zeros((1,), np.int32)
    out = np.asarray(gated_conv2d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), kh=3, kw=3))
    assert out.max() == 2**15 - 1


def test_leak_truncates_toward_zero():
    v = jnp.asarray([7, -7, 8, -8, 3, -3, 0], jnp.int32)
    np.testing.assert_array_equal(np.asarray(leak(v)), [1, -1, 2, -2, 0, 0, 0])


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(1, 4),
    n=st.integers(1, 40),
    vth=st.integers(1, 96),
    seed=st.integers(0, 2**31),
)
def test_lif_pallas_matches_oracle(t, n, vth, seed):
    rng = np.random.default_rng(seed)
    accs = rng.integers(-200, 201, (t, n)).astype(np.int32)
    got = lif_chain_pallas(jnp.asarray(accs), vth)
    want = lif_chain(jnp.asarray(accs), vth)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lif_step_hard_reset():
    acc = jnp.asarray([100, 10], jnp.int32)
    vmem = jnp.zeros(2, jnp.int32)
    fired = jnp.zeros(2, jnp.int32)
    s, v, f = lif_step(acc, vmem, fired, jnp.asarray(32, jnp.int32))
    np.testing.assert_array_equal(np.asarray(s), [1, 0])
    # Fired neuron's residual is dropped next step.
    s2, v2, _ = lif_step(jnp.asarray([0, 0], jnp.int32), v, f, jnp.asarray(32, jnp.int32))
    assert int(v2[0]) == 0  # leak(0) + 0
    assert int(v2[1]) == 2  # leak(10) = 2


def test_lif_vmem_saturates_8bit():
    acc = jnp.asarray([500], jnp.int32)
    s, v, _ = lif_step(acc, jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32), jnp.asarray(1000, jnp.int32))
    assert int(v[0]) == 127
    assert int(s[0]) == 0


def test_maxpool_or():
    x = jnp.asarray(np.array([[[0, 1, 0, 0], [0, 0, 0, 0]]]), jnp.int32)
    np.testing.assert_array_equal(np.asarray(maxpool2x2_or(x)), [[[1, 0]]])


def test_sat_i16_bounds():
    v = jnp.asarray([40_000, -40_000, 5], jnp.int32)
    np.testing.assert_array_equal(np.asarray(sat_i16(v)), [32767, -32768, 5])
