"""Layer-2 model checks: topology pins (mirroring rust
`model::topology::tests`), float forward shapes, quantization rules, and
float↔quant consistency of the LIF constants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    HEAD_CH,
    VTH,
    build_network,
    fold_and_quantize,
    init_bn_stats,
    init_params,
    snn_forward_float,
    snn_forward_quant,
    variant_forward,
)
from compile import train as T


def test_topology_matches_rust_geometry():
    net = build_network("tiny", t=3, ts_mode="C2")
    assert len(net.layers) == 19
    assert net.grid() == (10, 6)
    enc, conv1 = net.layer("enc"), net.layer("conv1")
    assert (enc.in_t, enc.out_t) == (1, 1)
    assert (conv1.in_t, conv1.out_t) == (1, 3)
    b1s1 = net.layer("b1.stack1")
    assert (b1s1.in_t, b1s1.out_t) == (3, 3)
    head = net.layer("head")
    assert (head.in_t, head.out_t) == (3, 1)
    assert head.c_out == HEAD_CH == 40
    agg = net.layer("b1.agg")
    assert agg.input_from == "b1.stack2" and agg.concat_with == "b1.short"
    assert agg.c_in == net.layer("b1.stack2").c_out + net.layer("b1.short").c_out


def test_full_scale_geometry():
    net = build_network("full", t=3, ts_mode="C2")
    assert net.grid() == (32, 18)
    p = T.num_params(net)
    assert 2_500_000 < p < 4_500_000


def test_c2b1_time_region():
    net = build_network("tiny", t=3, ts_mode="C2B", ts_blocks=1)
    assert (net.layer("b1.stack2").in_t, net.layer("b1.stack2").out_t) == (1, 1)
    assert (net.layer("b1.agg").in_t, net.layer("b1.agg").out_t) == (1, 3)
    assert (net.layer("b2.stack1").in_t, net.layer("b2.stack1").out_t) == (3, 3)


def test_mixed_time_steps_reduce_ops():
    base = T.dense_ops(build_network("tiny", ts_mode="uniform"))
    c2 = T.dense_ops(build_network("tiny", ts_mode="C2"))
    assert c2 < base
    assert 0.05 < 1 - c2 / base < 0.6


@pytest.fixture(scope="module")
def tiny_setup():
    net = build_network("tiny")
    params = init_params(net, 0)
    bn = init_bn_stats(net)
    return net, params, bn


def test_float_forward_shapes(tiny_setup):
    net, params, bn = tiny_setup
    imgs = jnp.zeros((2, 3, net.input_h, net.input_w), jnp.float32)
    head, new_bn, rates = snn_forward_float(params, bn, net, imgs, train=True)
    gw, gh = net.grid()
    assert head.shape == (2, HEAD_CH, gh, gw)
    assert set(new_bn) == {l.name for l in net.layers if l.kind != "output"}
    assert all(0.0 <= float(r) <= 1.0 for r in rates.values())


def test_variant_forward_shapes(tiny_setup):
    net, params, bn = tiny_setup
    imgs = jnp.zeros((1, 3, net.input_h, net.input_w), jnp.float32)
    for variant in ["ann", "qnn", "bnn"]:
        head, _ = variant_forward(params, bn, net, imgs, variant=variant, train=False)
        gw, gh = net.grid()
        assert head.shape == (1, HEAD_CH, gh, gw), variant


def test_quantization_rules(tiny_setup):
    net, params, bn = tiny_setup
    q = fold_and_quantize(params, bn, net)
    assert set(q) == {l.name for l in net.layers}
    for name, lw in q.items():
        assert lw.w.dtype == np.int8
        # vth_q = round(0.5/scale); spike layers capped for 8-bit vmem, the
        # residual-free encoding layer only by the 16-bit accumulator.
        cap = 8000 if name == "enc" else 96
        assert 1 <= lw.vth_q <= cap + 1, name
        assert abs(lw.vth_q - round(VTH / lw.scale)) <= 1
    # Encoding layer folds /255 → much smaller scale than hidden layers.
    assert q["enc"].scale < q["b1.stack1"].scale
    # Its weights must survive quantization (regression: the old global
    # floor rounded them all to zero).
    assert (q["enc"].w != 0).any()


def test_quant_forward_is_deterministic_and_shaped(tiny_setup):
    net, params, bn = tiny_setup
    q = fold_and_quantize(params, bn, net)
    img = jnp.asarray(np.random.default_rng(0).integers(0, 256, (3, net.input_h, net.input_w)), jnp.uint8)
    fwd = jax.jit(lambda im: snn_forward_quant(q, net, im))
    a = np.asarray(fwd(img))
    b = np.asarray(fwd(img))
    gw, gh = net.grid()
    assert a.shape == (HEAD_CH, gh, gw)
    assert a.dtype == np.int32
    np.testing.assert_array_equal(a, b)


def test_spike_fn_surrogate_gradient():
    from compile.model import spike_fn

    g = jax.grad(lambda u: spike_fn(u).sum())(jnp.asarray([0.5, 0.2, 5.0]))
    # Inside the rectangular window (|u-0.5|<0.5) gradient 1, outside 0.
    np.testing.assert_array_equal(np.asarray(g), [1.0, 1.0, 0.0])
