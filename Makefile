# Top-level driver: python build path (one-shot) + rust request path.

ARTIFACTS ?= artifacts
CARGO ?= cargo
PY ?= python3

.PHONY: all build test bench bench-smoke artifacts artifacts-quick fmt clippy clean

all: build

# Tier-1 verification target.
build:
	cd rust && $(CARGO) build --release

test:
	cd rust && $(CARGO) test -q

fmt:
	cd rust && $(CARGO) fmt --check

clippy:
	cd rust && $(CARGO) clippy --all-targets -- -D warnings

# Paper figure/table reproductions (see README.md for the bench → figure map).
bench:
	cd rust && $(CARGO) bench

# Quick serving-path smoke: streaming engine + multi-core simulator +
# multi-chip cluster + pipelined executor + wall-clock stage serving
# with a minimal sample budget (same as the CI bench step). perf_hotpath,
# perf_prosperity and perf_temporal hard-assert the word-parallel,
# product-sparsity and temporal-delta one-to-all paths are bit-exact
# with the reference (perf_temporal additionally gates the cycle model's
# lock-step and the fresh-MAC drop at full correlation), the dse smoke
# cycle-verifies a decimated Pareto sweep, perf_loadgen asserts p99
# total latency is monotone in offered load, perf_slo asserts shedding
# holds the admitted p99 at the target with >= 80% of capacity as
# goodput (blocking blows the same target), and the traced detect run
# self-checks that the Chrome trace parses with non-empty histograms.
# The --expect-shed detect leg drives the SLO path end to end at far
# over-capacity offered load and fails unless admission control sheds.
bench-smoke:
	cd rust && SCSNN_BENCH_SECS=0.05 $(CARGO) bench --bench perf_throughput && \
	SCSNN_BENCH_SECS=0.05 $(CARGO) bench --bench fig06_parallelism && \
	SCSNN_BENCH_SECS=0.05 $(CARGO) bench --bench perf_cluster && \
	SCSNN_BENCH_SECS=0.05 $(CARGO) bench --bench perf_pipeline && \
	SCSNN_BENCH_SECS=0.05 $(CARGO) bench --bench perf_hotpath && \
	SCSNN_BENCH_SECS=0.05 $(CARGO) bench --bench perf_prosperity && \
	SCSNN_BENCH_SECS=0.05 $(CARGO) bench --bench perf_temporal && \
	SCSNN_BENCH_SECS=0.05 $(CARGO) bench --bench perf_loadgen && \
	SCSNN_BENCH_SECS=0.05 $(CARGO) bench --bench perf_slo && \
	SCSNN_PROP_CASES=16 $(CARGO) test -q --test stage_serving && \
	SCSNN_PROP_CASES=16 $(CARGO) test -q --test prosperity_conformance && \
	SCSNN_PROP_CASES=16 $(CARGO) test -q --test temporal_conformance && \
	$(CARGO) test -q --test trace_determinism && \
	$(CARGO) test -q --test slo_serving && \
	$(CARGO) run --release -- simulate --scale tiny --chips 2 --pipeline 2 && \
	$(CARGO) run --release -- simulate --scale tiny --datapath prosperity && \
	$(CARGO) run --release -- simulate --scale tiny --datapath temporal-delta && \
	$(CARGO) run --release -- dse --scale tiny --max-points 32 --verify 3 && \
	$(CARGO) run --release -- detect --scale tiny --frames 8 --chips 2 --pipeline 2 \
	  --trace /tmp/trace.json --arrivals poisson:200 && \
	$(CARGO) run --release -- detect --scale tiny --frames 12 \
	  --arrivals poisson:100000 --slo p99:8 --expect-shed && \
	$(CARGO) run --release -- trace --frames 8 --out /tmp/trace_cmd.json

# One-shot python build path: datasets + training + quantized weights +
# AOT HLO artifact + metrics.json. Requires jax (see python/).
artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../$(ARTIFACTS)

# Much faster smoke version of the artifact build (short training).
artifacts-quick:
	cd python && $(PY) -m compile.aot --out-dir ../$(ARTIFACTS) --quick

clean:
	cd rust && $(CARGO) clean
