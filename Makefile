# Top-level driver: python build path (one-shot) + rust request path.

ARTIFACTS ?= artifacts
CARGO ?= cargo
PY ?= python3

.PHONY: all build test bench artifacts artifacts-quick fmt clippy clean

all: build

# Tier-1 verification target.
build:
	cd rust && $(CARGO) build --release

test:
	cd rust && $(CARGO) test -q

fmt:
	cd rust && $(CARGO) fmt --check

clippy:
	cd rust && $(CARGO) clippy -- -D warnings

# Paper figure/table reproductions (see README.md for the bench → figure map).
bench:
	cd rust && $(CARGO) bench

# One-shot python build path: datasets + training + quantized weights +
# AOT HLO artifact + metrics.json. Requires jax (see python/).
artifacts:
	cd python && $(PY) -m compile.aot --out-dir ../$(ARTIFACTS)

# Much faster smoke version of the artifact build (short training).
artifacts-quick:
	cd python && $(PY) -m compile.aot --out-dir ../$(ARTIFACTS) --quick

clean:
	cd rust && $(CARGO) clean
